//! Run configuration: which artifact config, which schedule, scale knobs.
//!
//! Serializable to/from JSON (configs/ dir, results metadata) via the
//! from-scratch util::json. CLI flags map 1:1 onto these fields.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::pipeline::mitigation::FixKind;
use crate::util::json::{self, Json};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Pipelined,
    Sequential,
    /// Pipelined for `pipelined_iters`, then drained + sequential.
    Hybrid,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        match s {
            "pipelined" => Ok(Mode::Pipelined),
            "sequential" | "non-pipelined" | "baseline" => Ok(Mode::Sequential),
            "hybrid" => Ok(Mode::Hybrid),
            _ => Err(anyhow!("unknown mode {s:?} (pipelined|sequential|hybrid)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Pipelined => "pipelined",
            Mode::Sequential => "sequential",
            Mode::Hybrid => "hybrid",
        }
    }
}

/// Which compute backend serves the stage programs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// XLA when artifacts + a real PJRT backend are available, native
    /// pure-Rust kernels otherwise.
    Auto,
    /// In-crate kernels; needs no artifacts and no Python step.
    Native,
    /// AOT-compiled PJRT programs (errors without artifacts/backend).
    Xla,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "auto" => Ok(Backend::Auto),
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            _ => Err(anyhow!("unknown backend {s:?} (auto|native|xla)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Native => "native",
            Backend::Xla => "xla",
        }
    }
}

/// Which runtime executes the schedule — orthogonal to `Backend`
/// (which compute substrate serves each stage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// Single-thread cycle-accurate register scheduler (staleness
    /// simulated by the schedule).
    Scheduler,
    /// One OS thread per partition with mpsc channel registers
    /// (staleness emergent from real concurrency).
    Threaded,
}

impl RuntimeKind {
    pub fn parse(s: &str) -> Result<RuntimeKind> {
        match s {
            "scheduler" => Ok(RuntimeKind::Scheduler),
            "threaded" => Ok(RuntimeKind::Threaded),
            _ => Err(anyhow!("unknown runtime {s:?} (scheduler|threaded)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuntimeKind::Scheduler => "scheduler",
            RuntimeKind::Threaded => "threaded",
        }
    }
}

/// What the threaded supervisor does when a worker fails (panic, hang,
/// or fatal error) mid-run. See DESIGN.md §8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnFailure {
    /// Propagate the first failure (default; matches pre-supervisor
    /// behavior).
    Fail,
    /// Tear down, restore the newest valid checkpoint, replay the data
    /// stream, and relaunch — up to `max_restarts` per segment.
    Restart,
    /// Like `Restart`, but when the retry budget is exhausted fall back
    /// to single-occupancy scheduling and finish degraded.
    Degrade,
}

impl OnFailure {
    pub fn parse(s: &str) -> Result<OnFailure> {
        match s {
            "fail" => Ok(OnFailure::Fail),
            "restart" => Ok(OnFailure::Restart),
            "degrade" => Ok(OnFailure::Degrade),
            _ => Err(anyhow!("unknown on-failure policy {s:?} (fail|restart|degrade)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OnFailure::Fail => "fail",
            OnFailure::Restart => "restart",
            OnFailure::Degrade => "degrade",
        }
    }
}

/// How the pipeline partition vector (PPV) is chosen — orthogonal to
/// `backend`, `runtime`, and `staleness_fix`. See DESIGN.md §10.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionMode {
    /// The config's recorded PPV: the hand-tabulated native manifest
    /// entry or the artifact contract (default; matches pre-axis runs).
    Manual,
    /// Profile-guided: solve the bottleneck-minimizing PPV from the
    /// analytic per-block cost model at the same stage count, then
    /// synthesize the full contract (native built-ins only; see
    /// `profile::auto_native_meta`).
    Auto,
}

impl PartitionMode {
    pub fn parse(s: &str) -> Result<PartitionMode> {
        match s {
            "manual" => Ok(PartitionMode::Manual),
            "auto" => Ok(PartitionMode::Auto),
            _ => Err(anyhow!("unknown partition mode {s:?} (manual|auto)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionMode::Manual => "manual",
            PartitionMode::Auto => "auto",
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact config name under artifacts/ (e.g. "resnet20_4s") or a
    /// built-in native config (see `backend::native_config_names`).
    pub config: String,
    pub mode: Mode,
    /// Compute backend (default Auto: XLA when ready, else native).
    pub backend: Backend,
    /// Runtime executing the schedule (default: cycle-accurate
    /// scheduler; `threaded` = thread-per-partition).
    pub runtime: RuntimeKind,
    pub iters: u64,
    /// Hybrid only: iterations of the pipelined phase.
    pub pipelined_iters: u64,
    pub seed: u64,
    /// Evaluate every N retired iterations (0 = only at the end).
    pub eval_every: u64,
    /// Synthetic dataset knobs (DESIGN.md §4).
    pub train_size: usize,
    pub test_size: usize,
    pub noise: f64,
    /// Optional directory with real MNIST/CIFAR files.
    pub data_dir: Option<PathBuf>,
    /// Train-time augmentation (random pad+crop, flip for CIFAR,
    /// per-channel normalize) — deterministic per (seed, epoch, sample).
    pub augment: bool,
    /// Decode/augment prefetch worker threads (0 = synchronous on the
    /// feed thread; output is bitwise identical either way).
    pub prefetch: usize,
    /// LR multiplier for the stale (non-final) partitions — Table 7's
    /// per-BKS learning rate.
    pub stale_lr_scale: f64,
    /// Initialize weights from a checkpoint instead of random init
    /// (cross-process hybrid: pipelined prefix in one run, non-pipelined
    /// tail in another).
    pub resume_from: Option<PathBuf>,
    /// Write a checkpoint of the final weights here.
    pub save_to: Option<PathBuf>,
    /// Failure policy for the threaded runtime (fail|restart|degrade).
    pub on_failure: OnFailure,
    /// Restart budget per training segment before giving up (Restart)
    /// or degrading (Degrade).
    pub max_restarts: u32,
    /// Base of the capped exponential relaunch backoff, in ms.
    pub restart_backoff_ms: u64,
    /// Save a rotating checkpoint every N retired iterations
    /// (0 = no periodic checkpoints; requires `ckpt_dir` when set).
    pub ckpt_every: u64,
    /// Directory for rotating periodic checkpoints. Passing it as
    /// `resume_from` resumes from the newest valid file inside.
    pub ckpt_dir: Option<PathBuf>,
    /// How many rotating checkpoints to keep in `ckpt_dir`.
    pub ckpt_keep: usize,
    /// Watchdog timeout: a stage with no heartbeat for this long is
    /// declared hung; responsive workers with no batch progress for
    /// this long are declared deadlocked.
    pub stall_timeout_ms: u64,
    /// Deterministic fault plan for soak tests (see pipeline::faults
    /// for the grammar); threaded runtime only.
    pub fault_plan: Option<String>,
    /// Stale-weight mitigation applied to the non-last partitions
    /// (none | stash | predict | correct; DESIGN.md §9). Orthogonal to
    /// `backend` and `runtime`.
    pub staleness_fix: FixKind,
    /// How the PPV is chosen (manual = recorded, auto = profile-guided
    /// bottleneck-minimizing solve). Orthogonal to every other axis.
    pub partition: PartitionMode,
}

impl RunConfig {
    pub fn new(config: &str) -> Self {
        RunConfig {
            config: config.to_string(),
            mode: Mode::Pipelined,
            backend: Backend::Auto,
            runtime: RuntimeKind::Scheduler,
            iters: 300,
            pipelined_iters: 0,
            seed: 42,
            eval_every: 0,
            train_size: 2048,
            test_size: 512,
            noise: 0.6,
            data_dir: None,
            augment: false,
            prefetch: 0,
            stale_lr_scale: 1.0,
            resume_from: None,
            save_to: None,
            on_failure: OnFailure::Fail,
            max_restarts: 3,
            restart_backoff_ms: 250,
            ckpt_every: 0,
            ckpt_dir: None,
            ckpt_keep: 3,
            stall_timeout_ms: 60_000,
            fault_plan: None,
            staleness_fix: FixKind::None,
            partition: PartitionMode::Manual,
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("config", json::s(&self.config)),
            ("mode", json::s(self.mode.name())),
            ("backend", json::s(self.backend.name())),
            ("runtime", json::s(self.runtime.name())),
            ("iters", json::num(self.iters as f64)),
            ("pipelined_iters", json::num(self.pipelined_iters as f64)),
            ("seed", json::num(self.seed as f64)),
            ("eval_every", json::num(self.eval_every as f64)),
            ("train_size", json::num(self.train_size as f64)),
            ("test_size", json::num(self.test_size as f64)),
            ("noise", json::num(self.noise)),
            (
                "data_dir",
                self.data_dir
                    .as_ref()
                    .map(|p| json::s(&p.display().to_string()))
                    .unwrap_or(Json::Null),
            ),
            ("augment", Json::Bool(self.augment)),
            ("prefetch", json::num(self.prefetch as f64)),
            ("stale_lr_scale", json::num(self.stale_lr_scale)),
            ("on_failure", json::s(self.on_failure.name())),
            ("max_restarts", json::num(self.max_restarts as f64)),
            ("restart_backoff_ms", json::num(self.restart_backoff_ms as f64)),
            ("ckpt_every", json::num(self.ckpt_every as f64)),
            (
                "ckpt_dir",
                self.ckpt_dir
                    .as_ref()
                    .map(|p| json::s(&p.display().to_string()))
                    .unwrap_or(Json::Null),
            ),
            ("ckpt_keep", json::num(self.ckpt_keep as f64)),
            ("stall_timeout_ms", json::num(self.stall_timeout_ms as f64)),
            (
                "fault_plan",
                self.fault_plan.as_ref().map(|p| json::s(p)).unwrap_or(Json::Null),
            ),
            ("staleness_fix", json::s(self.staleness_fix.name())),
            ("partition", json::s(self.partition.name())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let config = j
            .get("config")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("run config missing 'config'"))?;
        let mut rc = RunConfig::new(config);
        if let Some(m) = j.get("mode").and_then(Json::as_str) {
            rc.mode = Mode::parse(m)?;
        }
        if let Some(b) = j.get("backend").and_then(Json::as_str) {
            rc.backend = Backend::parse(b)?;
        }
        if let Some(r) = j.get("runtime").and_then(Json::as_str) {
            rc.runtime = RuntimeKind::parse(r)?;
        }
        let getn = |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        rc.iters = getn("iters", rc.iters as f64) as u64;
        rc.pipelined_iters = getn("pipelined_iters", 0.0) as u64;
        rc.seed = getn("seed", rc.seed as f64) as u64;
        rc.eval_every = getn("eval_every", 0.0) as u64;
        rc.train_size = getn("train_size", rc.train_size as f64) as usize;
        rc.test_size = getn("test_size", rc.test_size as f64) as usize;
        rc.noise = getn("noise", rc.noise);
        rc.stale_lr_scale = getn("stale_lr_scale", 1.0);
        if let Some(d) = j.get("data_dir").and_then(Json::as_str) {
            rc.data_dir = Some(PathBuf::from(d));
        }
        if let Some(a) = j.get("augment").and_then(Json::as_bool) {
            rc.augment = a;
        }
        rc.prefetch = getn("prefetch", 0.0) as usize;
        if let Some(p) = j.get("on_failure").and_then(Json::as_str) {
            rc.on_failure = OnFailure::parse(p)?;
        }
        rc.max_restarts = getn("max_restarts", rc.max_restarts as f64) as u32;
        rc.restart_backoff_ms = getn("restart_backoff_ms", rc.restart_backoff_ms as f64) as u64;
        rc.ckpt_every = getn("ckpt_every", 0.0) as u64;
        if let Some(d) = j.get("ckpt_dir").and_then(Json::as_str) {
            rc.ckpt_dir = Some(PathBuf::from(d));
        }
        rc.ckpt_keep = getn("ckpt_keep", rc.ckpt_keep as f64) as usize;
        rc.stall_timeout_ms = getn("stall_timeout_ms", rc.stall_timeout_ms as f64) as u64;
        if let Some(p) = j.get("fault_plan").and_then(Json::as_str) {
            rc.fault_plan = Some(p.to_string());
        }
        if let Some(f) = j.get("staleness_fix").and_then(Json::as_str) {
            rc.staleness_fix = FixKind::parse(f)?;
        }
        if let Some(p) = j.get("partition").and_then(Json::as_str) {
            rc.partition = PartitionMode::parse(p)?;
        }
        Ok(rc)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut rc = RunConfig::new("resnet20_4s");
        rc.mode = Mode::Hybrid;
        rc.pipelined_iters = 123;
        rc.noise = 0.4;
        rc.data_dir = Some(PathBuf::from("/tmp/data"));
        let j = rc.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.mode, Mode::Hybrid);
        assert_eq!(back.pipelined_iters, 123);
        assert_eq!(back.data_dir, rc.data_dir);
        assert!((back.noise - 0.4).abs() < 1e-12);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(Mode::parse("baseline").unwrap(), Mode::Sequential);
        assert_eq!(Mode::parse("hybrid").unwrap(), Mode::Hybrid);
        assert!(Mode::parse("nope").is_err());
    }

    #[test]
    fn backend_parsing_and_roundtrip() {
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("xla").unwrap(), Backend::Xla);
        assert_eq!(Backend::parse("auto").unwrap(), Backend::Auto);
        assert!(Backend::parse("tpu").is_err());
        let mut rc = RunConfig::new("quickstart_lenet");
        assert_eq!(rc.backend, Backend::Auto); // default
        rc.backend = Backend::Native;
        let back = RunConfig::from_json(&rc.to_json()).unwrap();
        assert_eq!(back.backend, Backend::Native);
    }

    #[test]
    fn runtime_parsing_and_roundtrip() {
        assert_eq!(RuntimeKind::parse("scheduler").unwrap(), RuntimeKind::Scheduler);
        assert_eq!(RuntimeKind::parse("threaded").unwrap(), RuntimeKind::Threaded);
        assert!(RuntimeKind::parse("gpu").is_err());
        let mut rc = RunConfig::new("native_lenet_small");
        assert_eq!(rc.runtime, RuntimeKind::Scheduler); // default
        rc.runtime = RuntimeKind::Threaded;
        let back = RunConfig::from_json(&rc.to_json()).unwrap();
        assert_eq!(back.runtime, RuntimeKind::Threaded);
        // configs without the key (older files) keep the default
        let legacy = Json::parse("{\"config\": \"x\"}").unwrap();
        assert_eq!(RunConfig::from_json(&legacy).unwrap().runtime, RuntimeKind::Scheduler);
    }

    #[test]
    fn on_failure_parsing() {
        assert_eq!(OnFailure::parse("fail").unwrap(), OnFailure::Fail);
        assert_eq!(OnFailure::parse("restart").unwrap(), OnFailure::Restart);
        assert_eq!(OnFailure::parse("degrade").unwrap(), OnFailure::Degrade);
        assert!(OnFailure::parse("retry").is_err());
        for p in [OnFailure::Fail, OnFailure::Restart, OnFailure::Degrade] {
            assert_eq!(OnFailure::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn fault_tolerance_fields_roundtrip() {
        let mut rc = RunConfig::new("native_lenet_small_4s");
        rc.on_failure = OnFailure::Degrade;
        rc.max_restarts = 5;
        rc.restart_backoff_ms = 40;
        rc.ckpt_every = 10;
        rc.ckpt_dir = Some(PathBuf::from("/tmp/ckpts"));
        rc.ckpt_keep = 2;
        rc.stall_timeout_ms = 1500;
        rc.fault_plan = Some("panic@1:12;corrupt@0".to_string());
        let back = RunConfig::from_json(&rc.to_json()).unwrap();
        assert_eq!(back.on_failure, OnFailure::Degrade);
        assert_eq!(back.max_restarts, 5);
        assert_eq!(back.restart_backoff_ms, 40);
        assert_eq!(back.ckpt_every, 10);
        assert_eq!(back.ckpt_dir, rc.ckpt_dir);
        assert_eq!(back.ckpt_keep, 2);
        assert_eq!(back.stall_timeout_ms, 1500);
        assert_eq!(back.fault_plan, rc.fault_plan);
        // legacy configs without the keys keep the defaults
        let legacy = Json::parse("{\"config\": \"x\"}").unwrap();
        let d = RunConfig::from_json(&legacy).unwrap();
        assert_eq!(d.on_failure, OnFailure::Fail);
        assert_eq!(d.max_restarts, 3);
        assert_eq!(d.ckpt_every, 0);
        assert_eq!(d.ckpt_dir, None);
        assert_eq!(d.stall_timeout_ms, 60_000);
        assert_eq!(d.fault_plan, None);
    }

    #[test]
    fn staleness_fix_roundtrip_and_legacy_default() {
        let mut rc = RunConfig::new("native_lenet_small_4s");
        assert_eq!(rc.staleness_fix, FixKind::None); // default
        for kind in FixKind::all() {
            rc.staleness_fix = kind;
            let back = RunConfig::from_json(&rc.to_json()).unwrap();
            assert_eq!(back.staleness_fix, kind);
        }
        // configs without the key (older files) keep the default
        let legacy = Json::parse("{\"config\": \"x\"}").unwrap();
        assert_eq!(RunConfig::from_json(&legacy).unwrap().staleness_fix, FixKind::None);
        // bogus values are an error, not a silent fallback
        let bogus = Json::parse("{\"config\": \"x\", \"staleness_fix\": \"wormhole\"}").unwrap();
        assert!(RunConfig::from_json(&bogus).is_err());
    }

    #[test]
    fn partition_mode_roundtrip_and_legacy_default() {
        assert_eq!(PartitionMode::parse("manual").unwrap(), PartitionMode::Manual);
        assert_eq!(PartitionMode::parse("auto").unwrap(), PartitionMode::Auto);
        assert!(PartitionMode::parse("magic").is_err());
        let mut rc = RunConfig::new("native_resnet20_4s");
        assert_eq!(rc.partition, PartitionMode::Manual); // default
        for mode in [PartitionMode::Manual, PartitionMode::Auto] {
            rc.partition = mode;
            let back = RunConfig::from_json(&rc.to_json()).unwrap();
            assert_eq!(back.partition, mode);
            assert_eq!(PartitionMode::parse(mode.name()).unwrap(), mode);
        }
        // configs without the key (older files) keep the default
        let legacy = Json::parse("{\"config\": \"x\"}").unwrap();
        assert_eq!(RunConfig::from_json(&legacy).unwrap().partition, PartitionMode::Manual);
        // bogus values are an error, not a silent fallback
        let bogus = Json::parse("{\"config\": \"x\", \"partition\": \"psychic\"}").unwrap();
        assert!(RunConfig::from_json(&bogus).is_err());
    }

    #[test]
    fn data_plane_fields_roundtrip_and_legacy_default() {
        let mut rc = RunConfig::new("native_lenet_small_4s");
        assert!(!rc.augment); // defaults
        assert_eq!(rc.prefetch, 0);
        rc.augment = true;
        rc.prefetch = 4;
        rc.data_dir = Some(PathBuf::from("/tmp/mnist"));
        let back = RunConfig::from_json(&rc.to_json()).unwrap();
        assert!(back.augment);
        assert_eq!(back.prefetch, 4);
        assert_eq!(back.data_dir, rc.data_dir);
        // configs without the keys (older files) keep the defaults
        let legacy = Json::parse("{\"config\": \"x\"}").unwrap();
        let d = RunConfig::from_json(&legacy).unwrap();
        assert!(!d.augment);
        assert_eq!(d.prefetch, 0);
    }

    #[test]
    fn save_load() {
        let rc = RunConfig::new("lenet5_4s");
        let p = std::env::temp_dir().join(format!("rc_{}.json", std::process::id()));
        rc.save(&p).unwrap();
        let back = RunConfig::load(&p).unwrap();
        assert_eq!(back.config, "lenet5_4s");
        std::fs::remove_file(&p).ok();
    }
}
