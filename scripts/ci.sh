#!/usr/bin/env bash
# Tier-1 verification: build, tests (incl. doctests), docs, coverage
# floor, formatting.
#
# Everything runs offline against the bundled stub backend (see
# rust/DESIGN.md §Backends); artifact/XLA-dependent tests skip
# themselves, while the native-backend suite executes everywhere.
# The coverage floor (scripts/test_floor.txt) counts *executed*
# (non-skipped) tests: a regression that turns native coverage back
# into skips fails CI even though every remaining test still passes.
# Doctests are folded into the same floor: they run as a separate,
# explicitly-counted pass (the main pass excludes them via
# --lib/--bins/--tests so nothing is counted twice).
# Pass --bench to also run the hot-path microbench and refresh
# results/BENCH_micro.json.
set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

cargo build --release --workspace

# --nocapture so the per-test "skipping:" markers reach the log.
TEST_LOG="$(mktemp)"
trap 'rm -f "$TEST_LOG"' EXIT
# --examples keeps the example binaries compiling (they hold no tests,
# so they add nothing to the counted totals).
cargo test -q --workspace --lib --bins --tests --examples -- --nocapture 2>&1 | tee "$TEST_LOG"

# Doctests: a separate pass appended to the same counted log.
cargo test -q --doc -p pipestale 2>&1 | tee -a "$TEST_LOG"

passed=$({ grep -Eo '[0-9]+ passed' "$TEST_LOG" || true; } | awk '{s+=$1} END {print s+0}')
skipped=$(grep -c 'skipping:' "$TEST_LOG" || true)
executed=$((passed - skipped))
floor=$(cat "$SCRIPT_DIR/test_floor.txt")
echo "[ci] tests: $passed passed, $skipped skipped -> $executed executed (floor $floor)"
if [ "$executed" -lt "$floor" ]; then
    echo "[ci] FAIL: executed test count $executed fell below the recorded floor $floor." >&2
    echo "[ci] (recomputed floor input: $passed passed - $skipped skipped = $executed executed)" >&2
    echo "[ci] If tests were intentionally removed, lower scripts/test_floor.txt;" >&2
    echo "[ci] otherwise something is skipping coverage that used to execute." >&2
    exit 1
fi

# Thread-count sensitivity: the threaded-native suite must pass both
# under the default parallel test harness (the run above) and fully
# serialized — concurrency bugs often hide at one thread count. This
# rerun is deliberately outside TEST_LOG so the executed-test floor
# counts each test once. The same suite then reruns across the GEMM
# thread-count axis: pinned to 1 GEMM thread (pure serial compute) and
# pinned to 4 (worker-pool dispatch even on small hosts), since the
# pipeline's bitwise invariants must hold at every GEMM thread count
# (DESIGN.md §7).
echo "[ci] rerunning threaded-native suite under RUST_TEST_THREADS=1"
RUST_TEST_THREADS=1 cargo test -q --test threaded_native
echo "[ci] rerunning threaded-native suite under PIPESTALE_GEMM_THREADS=1"
PIPESTALE_GEMM_THREADS=1 cargo test -q --test threaded_native
echo "[ci] rerunning threaded-native suite under PIPESTALE_GEMM_THREADS=4"
PIPESTALE_GEMM_THREADS=4 cargo test -q --test threaded_native

# Fault-injection soak: a P=4 native ResNet pipelined run that takes a
# mid-train worker panic, a hung stage (watchdog kill), and a corrupted
# checkpoint save, and must still complete under the supervisor
# (DESIGN.md §8). Exercises the released binary end to end, CLI
# included — distinct from tests/resilience.rs's in-process coverage.
echo "[ci] fault-injection soak (panic + stall + corrupt, P=4)"
SOAK_DIR="$(mktemp -d)"
trap 'rm -f "$TEST_LOG"; rm -rf "$SOAK_DIR"' EXIT
./target/release/pipestale train --config native_resnet_small_4s \
    --backend native --runtime threaded --mode pipelined --iters 40 \
    --train-size 128 --test-size 32 \
    --ckpt-every 10 --ckpt-dir "$SOAK_DIR" --ckpt-keep 3 \
    --stall-timeout-ms 2000 --on-failure degrade --max-restarts 2 \
    --restart-backoff-ms 50 --fault-plan 'panic@1:12;stall@2:30:4000;corrupt@0'

# Staleness-mitigation matrix smoke: every --staleness-fix on both
# runtimes through the released binary (DESIGN.md §9) — keeps the CLI
# axis wired end to end, distinct from tests/mitigation.rs's
# in-process equivalence coverage.
echo "[ci] staleness-mitigation matrix smoke (4 fixes x 2 runtimes, P=4)"
for fix in none stash predict correct; do
    for rt in scheduler threaded; do
        ./target/release/pipestale train --config native_lenet_small_4s \
            --backend native --runtime "$rt" --mode pipelined \
            --staleness-fix "$fix" --iters 12 --train-size 96 --test-size 32
    done
done

# Profile-guided auto-partition smoke (DESIGN.md §10): --partition auto
# must resolve on both runtimes through the released binary, and the
# analysis commands must accept auto-partitioned (synthesized,
# artifact-free) configs — distinct from tests/partition.rs's
# in-process solver/determinism coverage.
echo "[ci] auto-partition smoke (2 configs x 2 runtimes, --partition auto)"
for cfg in native_lenet_small_4s native_resnet_small_4s; do
    for rt in scheduler threaded; do
        ./target/release/pipestale train --config "$cfg" \
            --backend native --runtime "$rt" --mode pipelined \
            --partition auto --iters 12 --train-size 96 --test-size 32
    done
    ./target/release/pipestale perfsim --config "$cfg" --partition auto
    ./target/release/pipestale memory --config "$cfg" --partition auto
done

# Data-plane smoke (DESIGN.md §11): generate real-format fixture
# datasets with the released binary, then train on them with the full
# streaming path (--data-dir + --augment + --prefetch) on both
# runtimes and both formats — the CLI leg of the determinism battery
# in tests/data_stream.rs, which then reruns fully serialized (the
# prefetcher must be race-free at every test-harness thread count).
echo "[ci] data-plane smoke (gen-data + streaming train, 2 datasets x 2 runtimes)"
DATA_DIR="$(mktemp -d)"
trap 'rm -f "$TEST_LOG"; rm -rf "$SOAK_DIR" "$DATA_DIR"' EXIT
./target/release/pipestale gen-data --dir "$DATA_DIR/mnist" \
    --dataset mnist --train 256 --test 64
./target/release/pipestale gen-data --dir "$DATA_DIR/cifar10" \
    --dataset cifar10 --train 128 --test 32
for rt in scheduler threaded; do
    ./target/release/pipestale train --config native_lenet_small_4s \
        --backend native --runtime "$rt" --mode pipelined --iters 24 \
        --data-dir "$DATA_DIR/mnist" --augment --prefetch 2
    ./target/release/pipestale train --config native_resnet_small_4s \
        --backend native --runtime "$rt" --mode pipelined --iters 12 \
        --data-dir "$DATA_DIR/cifar10" --augment --prefetch 2
done
echo "[ci] rerunning data_stream suite under RUST_TEST_THREADS=1"
RUST_TEST_THREADS=1 cargo test -q --test data_stream

# Docs build warning-free: #![warn(missing_docs)] is enabled in
# src/lib.rs, so -D warnings turns an undocumented public item (or a
# broken intra-doc link) into a CI failure.
echo "[ci] building docs with -D warnings"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p pipestale

cargo fmt --all --check

if [[ "${1:-}" == "--bench" ]]; then
    cargo bench --bench bench_micro_hotpath
    # The bench must have produced a parseable machine-readable report:
    # downstream tooling reads results/BENCH_micro.json, so an empty or
    # truncated write is a CI failure, not a warning.
    # results_root() honors PIPESTALE_RESULTS and defaults to
    # rust/results/ (we are already cd'd into rust/).
    BENCH_JSON="${PIPESTALE_RESULTS:-results}/BENCH_micro.json"
    if [ ! -s "$BENCH_JSON" ]; then
        echo "[ci] FAIL: $BENCH_JSON missing or empty after --bench run." >&2
        exit 1
    fi
    if command -v python3 > /dev/null 2>&1; then
        python3 -m json.tool "$BENCH_JSON" > /dev/null \
            || { echo "[ci] FAIL: $BENCH_JSON is not valid JSON." >&2; exit 1; }
    else
        grep -q '"schema": "pipestale/bench_micro/v2"' "$BENCH_JSON" \
            || { echo "[ci] FAIL: $BENCH_JSON lacks the bench_micro/v2 schema tag." >&2; exit 1; }
    fi
    echo "[ci] BENCH_micro.json validated"

    # The auto-vs-manual partition bench (table5 §0b) must likewise
    # leave a parseable report behind: downstream tooling reads
    # results/BENCH_partition.json (predicted vs emergent stage costs).
    PIPESTALE_FAST=1 cargo bench --bench bench_table5_speedup
    PART_JSON="${PIPESTALE_RESULTS:-results}/BENCH_partition.json"
    if [ ! -s "$PART_JSON" ]; then
        echo "[ci] FAIL: $PART_JSON missing or empty after --bench run." >&2
        exit 1
    fi
    if command -v python3 > /dev/null 2>&1; then
        python3 -m json.tool "$PART_JSON" > /dev/null \
            || { echo "[ci] FAIL: $PART_JSON is not valid JSON." >&2; exit 1; }
    else
        grep -q '"schema": "pipestale/bench_partition/v1"' "$PART_JSON" \
            || { echo "[ci] FAIL: $PART_JSON lacks the bench_partition/v1 schema tag." >&2; exit 1; }
    fi
    echo "[ci] BENCH_partition.json validated"
fi
