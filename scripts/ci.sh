#!/usr/bin/env bash
# Tier-1 verification: build, tests, formatting.
#
# Everything runs offline against the bundled stub backend (see
# rust/DESIGN.md §Backends); artifact/XLA-dependent tests skip
# themselves. Pass --bench to also run the hot-path microbench and
# refresh results/BENCH_micro.json.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release --workspace
cargo test -q --workspace
cargo fmt --all --check

if [[ "${1:-}" == "--bench" ]]; then
    cargo bench --bench bench_micro_hotpath
fi
