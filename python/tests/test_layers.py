"""L2 layer-zoo unit tests: shape propagation, BN/dropout semantics,
residual carry discipline."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.layers import (Act, BatchNorm, Conv, Dense, Dropout, Flatten,
                            GlobalAvgPool, Layer, MaxPool, ResEnd, ResStart,
                            init_value)


def _params_state(layer, rng):
    params = {n: jnp.asarray(init_value(s, i, f, rng))
              for n, s, i, f in layer.param_specs()}
    state = {n: jnp.asarray(init_value(s, i, 0, rng))
             for n, s, i in layer.state_specs()}
    return params, state


def _run(layer, x, train=True, seed=0, rng=None):
    rng = rng or np.random.default_rng(0)
    params, state = _params_state(layer, rng)
    out, up = layer.apply(params, state, (x,), train=train,
                          seed=jnp.int32(seed))
    return out, up, params, state


def test_conv_shape_propagation_matches_apply():
    rng = np.random.default_rng(0)
    for stride, padding in [(1, "SAME"), (2, "SAME"), (1, "VALID")]:
        op = Conv("c", 3, 8, 3, stride, padding)
        layer = Layer("l", [op])
        x = jnp.asarray(rng.normal(size=(2, 9, 9, 3)).astype(np.float32))
        out, _, _, _ = _run(layer, x)
        assert out[0].shape == layer.out_shapes((x.shape,))[0]


def test_maxpool_shape_and_value():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    layer = Layer("l", [MaxPool("p", 2)])
    out, _, _, _ = _run(layer, x)
    assert out[0].shape == (1, 2, 2, 1)
    np.testing.assert_allclose(np.asarray(out[0]).ravel(), [5, 7, 13, 15])


def test_batchnorm_train_normalizes_and_updates_state():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(3.0, 2.0, size=(64, 4, 4, 8)).astype(np.float32))
    layer = Layer("l", [BatchNorm("bn", 8)])
    out, up, params, state = _run(layer, x, train=True)
    y = np.asarray(out[0])
    assert abs(y.mean()) < 1e-3 and abs(y.std() - 1.0) < 1e-2
    # running stats moved toward batch stats
    assert np.all(np.asarray(up["bn/mean"]) != np.asarray(state["bn/mean"]))


def test_batchnorm_eval_uses_running_stats():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(8, 2, 2, 4)).astype(np.float32))
    layer = Layer("l", [BatchNorm("bn", 4)])
    params, state = _params_state(layer, rng)
    out, up = layer.apply(params, state, (x,), train=False, seed=jnp.int32(0))
    # with mean=0 var=1 state, eval BN is (x)*gamma+beta = x
    np.testing.assert_allclose(out[0], x, rtol=1e-4, atol=1e-4)
    assert up == {}


def test_dropout_train_scales_and_is_seed_deterministic():
    rng = np.random.default_rng(3)
    x = jnp.ones((4, 100), jnp.float32)
    layer = Layer("l", [Dropout("do", 0.5, salt=1)])
    out1, _, _, _ = _run(layer, x, train=True, seed=42, rng=rng)
    out2, _, _, _ = _run(layer, x, train=True, seed=42, rng=rng)
    out3, _, _, _ = _run(layer, x, train=True, seed=43, rng=rng)
    np.testing.assert_array_equal(out1[0], out2[0])  # same seed -> same mask
    assert not np.array_equal(np.asarray(out1[0]), np.asarray(out3[0]))
    vals = np.unique(np.asarray(out1[0]))
    assert set(vals.tolist()) <= {0.0, 2.0}  # inverted dropout at p=0.5


def test_dropout_eval_is_identity():
    x = jnp.ones((4, 10), jnp.float32)
    out, _, _, _ = _run(Layer("l", [Dropout("do", 0.9)]), x, train=False)
    np.testing.assert_array_equal(out[0], x)


def test_residual_identity_block_adds_skip():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 4, 4, 8)).astype(np.float32))
    layer = Layer("l", [ResStart("s"), ResEnd("e", 8, 8, 1)])
    out, _, _, _ = _run(layer, x)
    np.testing.assert_allclose(out[0], 2 * x, rtol=1e-5)


def test_residual_projection_changes_shape():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 8)).astype(np.float32))
    start = Layer("a", [ResStart("s"), Conv("c", 8, 16, 3, 2, bias=False)])
    end = Layer("b", [ResEnd("e", 8, 16, 2)])
    out, _, p1, s1 = _run(start, x)
    rng2 = np.random.default_rng(6)
    p2 = {n: jnp.asarray(init_value(s, i, f, rng2))
          for n, s, i, f in end.param_specs()}
    s2 = {n: jnp.asarray(init_value(s, i, 0, rng2))
          for n, s, i in end.state_specs()}
    out2, _ = end.apply(p2, s2, out, train=True, seed=jnp.int32(0))
    assert out2[0].shape == (2, 4, 4, 16)
    assert len(out2) == 1  # skip consumed


def test_ops_pass_through_extra_carry():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 4, 4, 3)).astype(np.float32))
    extra = jnp.ones((2, 5), jnp.float32)
    layer = Layer("l", [Conv("c", 3, 4, 3), Act("a"), BatchNorm("bn", 4)])
    params, state = _params_state(layer, rng)
    out, _ = layer.apply(params, state, (x, extra), train=True,
                         seed=jnp.int32(0))
    assert len(out) == 2
    np.testing.assert_array_equal(out[1], extra)


def test_global_avg_pool_and_flatten():
    x = jnp.arange(2 * 2 * 2 * 3, dtype=jnp.float32).reshape(2, 2, 2, 3)
    layer = Layer("l", [GlobalAvgPool("g"), Flatten("f")])
    out, _, _, _ = _run(layer, x)
    assert out[0].shape == (2, 3)
    np.testing.assert_allclose(out[0][0], np.asarray(x[0]).mean(axis=(0, 1)))


def test_init_value_statistics():
    rng = np.random.default_rng(8)
    he = init_value((1000,), "he", 50, rng)
    assert abs(he.std() - np.sqrt(2 / 50)) < 0.02
    assert np.all(init_value((3, 3), "zeros", 0, rng) == 0)
    assert np.all(init_value((3, 3), "ones", 0, rng) == 1)
    gl = init_value((100, 100), "glorot", 100, rng)
    assert np.abs(gl).max() <= np.sqrt(6 / 200) + 1e-6


def test_dense_layer_flops():
    layer = Layer("l", [Dense("d", 10, 20)])
    assert layer.flops_per_sample(((1, 10),)) == 2 * 10 * 20
