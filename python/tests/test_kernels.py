"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/strides/paddings/activations; assert_allclose
against ref.py. This is the core correctness signal for the kernels that
end up inside every lowered stage program.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (conv2d, conv2d_pallas, conv2d_ref, dense,
                             dense_pallas, explicit_padding, matmul_ref,
                             mxu_utilization_estimate, vmem_footprint_bytes)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

@given(
    n=st.sampled_from([1, 2, 3, 4]),
    hw=st.sampled_from([4, 7, 8, 12]),
    cin=st.sampled_from([1, 3, 4, 8]),
    cout=st.sampled_from([1, 4, 8, 16]),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, hw, cin, cout, k, stride, padding, seed):
    if padding == "VALID" and hw < k:
        return
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, hw, hw, cin)
    w = _rand(rng, k, k, cin, cout)
    got = conv2d_pallas(x, w, stride=stride, padding=padding)
    want = conv2d_ref(x, w, stride=stride, padding=padding)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1), stride=st.sampled_from([1, 2]))
def test_conv2d_grads_match_ref(seed, stride):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 2, 8, 8, 3)
    w = _rand(rng, 3, 3, 3, 8)

    def f_pallas(x, w):
        return jnp.sum(conv2d(x, w, stride, "SAME") ** 2)

    def f_ref(x, w):
        return jnp.sum(conv2d_ref(x, w, stride=stride, padding="SAME") ** 2)

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gw, rw, rtol=1e-3, atol=1e-3)


def test_conv2d_numeric_gradcheck():
    """Finite-difference check on a tiny case (independent of jax.vjp)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1, 5, 5, 2)).astype(np.float32)
    w = rng.normal(size=(3, 3, 2, 2)).astype(np.float32)

    def f(wflat):
        wr = jnp.asarray(wflat.reshape(w.shape))
        return float(jnp.sum(conv2d(jnp.asarray(x), wr)))

    g = jax.grad(lambda w_: jnp.sum(conv2d(jnp.asarray(x), w_)))(jnp.asarray(w))
    g = np.asarray(g).ravel()
    eps = 1e-3
    idxs = rng.choice(w.size, size=6, replace=False)
    for i in idxs:
        wp = w.ravel().copy(); wp[i] += eps
        wm = w.ravel().copy(); wm[i] -= eps
        fd = (f(wp) - f(wm)) / (2 * eps)
        assert abs(fd - g[i]) < 5e-2, (i, fd, g[i])


def test_conv2d_bias_via_ref():
    rng = np.random.default_rng(1)
    x = _rand(rng, 2, 6, 6, 3)
    w = _rand(rng, 3, 3, 3, 4)
    b = _rand(rng, 4)
    np.testing.assert_allclose(
        conv2d_pallas(x, w) + b, conv2d_ref(x, w, b), rtol=1e-4, atol=1e-4)


def test_explicit_padding_same_odd_even():
    assert explicit_padding("SAME", 3, 3, 1, 1, h=8, w=8) == ((1, 1), (1, 1))
    assert explicit_padding("SAME", 3, 3, 2, 2, h=8, w=8) == ((0, 1), (0, 1))
    assert explicit_padding("VALID", 5, 5) == ((0, 0), (0, 0))
    assert explicit_padding(((2, 2), (0, 1)), 5, 5) == ((2, 2), (0, 1))


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@given(
    m=st.sampled_from([1, 2, 8, 33]),
    k=st.sampled_from([1, 7, 64]),
    n=st.sampled_from([1, 10, 128]),
    act=st.sampled_from(["none", "relu", "tanh"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = dense_pallas(x, w, b, activation=act)
    want = matmul_ref(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(act=st.sampled_from(["none", "relu", "tanh"]),
       seed=st.integers(0, 2**31 - 1))
def test_dense_grads_match_ref(act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, 4, 9), _rand(rng, 9, 7), _rand(rng, 7)

    g = jax.grad(lambda x_, w_, b_: jnp.sum(dense(x_, w_, b_, act) ** 2),
                 argnums=(0, 1, 2))(x, w, b)
    r = jax.grad(
        lambda x_, w_, b_: jnp.sum(matmul_ref(x_, w_, b_, activation=act) ** 2),
        argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(g, r):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# perf-model helpers
# ---------------------------------------------------------------------------

def test_vmem_footprint_positive_and_monotone():
    small = vmem_footprint_bytes(32, 8, 8, 16, 3, 3, 16)
    big = vmem_footprint_bytes(32, 32, 32, 16, 3, 3, 16)
    assert 0 < small < big


def test_mxu_utilization_bounds():
    assert mxu_utilization_estimate(3, 16) < 0.05
    assert mxu_utilization_estimate(128, 128) == 1.0
    assert mxu_utilization_estimate(256, 256) == 1.0
