"""Stage-splitter correctness: the heart of the L2<->L3 contract.

Composed per-stage programs must equal the monolithic model — forward
(eval and train) and gradients — for every PPV shape we exercise,
including cuts inside residual blocks (multi-tensor carries).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import models, stages
from compile.layers import init_value

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _init_model(name, width, seed=0):
    m = models.build_model(name, width)
    rng = np.random.default_rng(seed)
    params, state = {}, {}
    for l in m.layers:
        for n, s, i, f in l.param_specs():
            params[n] = jnp.asarray(init_value(s, i, f, rng))
        for n, s, i in l.state_specs():
            state[n] = jnp.asarray(init_value(s, i, 0, rng))
    return m, params, state


def _staged_eval(m, params, state, ppv, x):
    parts = stages.split(m, ppv)
    carry = (x,)
    for i, p in enumerate(parts):
        args = ([params[n] for n in p.param_names]
                + [state[n] for n in p.state_names])
        if i == len(parts) - 1:
            return stages.make_last_eval(p)(*args, *carry)[0]
        carry = tuple(stages.make_fwd_eval(p)(*args, *carry))


def _staged_train_grads(m, params, state, ppv, x, labels, seed=7):
    parts = stages.split(m, ppv)
    carries = stages.carry_shapes(m, ppv, x.shape[0])
    sd = jnp.int32(seed)
    carry, saved = (x,), []
    for i, p in enumerate(parts[:-1]):
        args = ([params[n] for n in p.param_names]
                + [state[n] for n in p.state_names])
        saved.append(carry)
        out = stages.make_fwd(p, train=True)(*args, sd, *carry)
        carry = tuple(out[:len(carries[i + 1])])
    p = parts[-1]
    args = ([params[n] for n in p.param_names]
            + [state[n] for n in p.state_names])
    out = stages.make_last(p)(*args, sd, *carry, labels)
    loss = out[0]
    gc = out[2:2 + len(carries[-1])]
    grads = dict(zip(p.param_names,
                     out[2 + len(carries[-1]):
                         2 + len(carries[-1]) + len(p.param_names)]))
    for i in range(len(parts) - 2, -1, -1):
        p = parts[i]
        args = ([params[n] for n in p.param_names]
                + [state[n] for n in p.state_names])
        out = stages.make_bwd(p, len(carries[i + 1]))(*args, sd, *saved[i], *gc)
        gc = out[:len(carries[i])]
        grads.update(zip(p.param_names, out[len(carries[i]):]))
    return float(loss), grads


def _monolithic_grads(m, params, state, x, labels, seed=7):
    def lossfn(ps):
        logits, _ = stages.full_forward(m, ps, state, x, train=True, seed=seed)
        logz = jax.nn.log_softmax(logits)
        return -jnp.mean(logz[jnp.arange(x.shape[0]), labels])
    return jax.grad(lossfn)(params)


@pytest.mark.parametrize("name,width,ppv", [
    ("lenet5", 1.0, [1]),
    ("lenet5", 1.0, [1, 2, 3, 4]),
    ("alexnet", 0.25, [1, 2]),
    ("resnet20", 0.5, [7]),
    ("resnet20", 0.5, [3, 5, 7]),
    ("resnet20", 0.5, [2]),          # cut inside a residual block
    ("resnet20", 0.5, [2, 4, 6, 8]),  # several in-block cuts
])
def test_staged_equals_monolithic(name, width, ppv):
    m, params, state = _init_model(name, width)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4,) + m.input_shape).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, size=(4,)).astype(np.int32))

    ref, _ = stages.full_forward(m, params, state, x, train=False)
    got = _staged_eval(m, params, state, ppv, x)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)

    _, grads = _staged_train_grads(m, params, state, ppv, x, labels)
    mono = _monolithic_grads(m, params, state, x, labels)
    for n in grads:
        np.testing.assert_allclose(grads[n], mono[n], rtol=1e-3, atol=1e-4,
                                   err_msg=n)


@given(p=st.integers(1, 19))
def test_resnet20_any_single_cut_composes(p):
    """Property: a single register after ANY layer 1..19 composes exactly
    (the Fig-6 sliding-stage experiment relies on this)."""
    m, params, state = _init_model("resnet20", 0.25, seed=2)
    rng = np.random.default_rng(p)
    x = jnp.asarray(rng.normal(size=(2,) + m.input_shape).astype(np.float32))
    ref, _ = stages.full_forward(m, params, state, x, train=False)
    got = _staged_eval(m, params, state, [p], x)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_split_validates_ppv():
    m, _, _ = _init_model("lenet5", 1.0)
    with pytest.raises(AssertionError):
        stages.split(m, [5])      # register after last layer is illegal
    with pytest.raises(AssertionError):
        stages.split(m, [3, 2])   # not increasing
    with pytest.raises(AssertionError):
        stages.split(m, [2, 2])   # duplicate


def test_partition_param_counts_sum_to_model():
    m, _, _ = _init_model("resnet20", 0.5)
    parts = stages.split(m, [7, 13])
    assert sum(p.param_count() for p in parts) == sum(m.layer_param_counts())


def test_percentage_stale_weights_definition():
    """Paper §3: %stale = sum_{i<=K} N_i / sum N_i. Check it is monotone in
    the register position for the slide experiment."""
    m, _, _ = _init_model("resnet20", 0.5)
    total = sum(m.layer_param_counts())
    pct = []
    for p in (3, 9, 15, 19):
        parts = stages.split(m, [p])
        pct.append(parts[0].param_count() / total)
    assert pct == sorted(pct) and pct[-1] > 0.5


def test_bwd_loss_grad_seed_consistency():
    """Dropout mask in bwd must equal the fwd mask (same seed)."""
    m, params, state = _init_model("alexnet", 0.25)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4,) + m.input_shape).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, size=(4,)).astype(np.int32))
    # staged grads with dropout active == monolithic grads at same seed
    _, grads = _staged_train_grads(m, params, state, [2, 5], x, labels, seed=11)
    mono = _monolithic_grads(m, params, state, x, labels, seed=11)
    for n in grads:
        np.testing.assert_allclose(grads[n], mono[n], rtol=1e-3, atol=1e-4,
                                   err_msg=n)
