"""Model-zoo structure tests: paper layer counts, shapes, PPV legality."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import models
from compile.experiments import MANIFEST, TABLE1_PPV
from compile.layers import init_value
from compile.stages import full_forward


@pytest.mark.parametrize("name,nlayers", [
    ("lenet5", 5), ("alexnet", 8), ("vgg16", 16),
    ("resnet20", 20), ("resnet56", 56), ("resnet110", 110),
])
def test_paper_layer_counts(name, nlayers):
    m = models.build_model(name, width_mult=0.25)
    assert m.num_layers == nlayers


def test_resnet_depth_must_be_6m_plus_2():
    with pytest.raises(AssertionError):
        models.build_model("resnet21")


@pytest.mark.parametrize("name", ["lenet5", "alexnet", "resnet20"])
def test_forward_shapes_and_finite(name):
    m = models.build_model(name, width_mult=0.25 if name != "lenet5" else 1.0)
    rng = np.random.default_rng(0)
    params, state = {}, {}
    for l in m.layers:
        for n, s, i, f in l.param_specs():
            params[n] = jnp.asarray(init_value(s, i, f, rng))
        for n, s, i in l.state_specs():
            state[n] = jnp.asarray(init_value(s, i, 0, rng))
    x = jnp.asarray(
        rng.normal(size=(2,) + m.input_shape).astype(np.float32))
    logits, updates = full_forward(m, params, state, x, train=True, seed=3)
    assert logits.shape == (2, m.num_classes)
    assert np.isfinite(np.asarray(logits)).all()
    # shape-propagation agrees with actual execution
    assert m.carry_shapes_after(2)[-1][0] == (2, m.num_classes)


def test_carry_shapes_batch_dim():
    m = models.build_model("resnet20", 0.5)
    shapes = m.carry_shapes_after(16)
    assert all(s[0] == 16 for group in shapes for s in group)


def test_width_mult_scales_params():
    full = sum(models.build_model("vgg16", 1.0).layer_param_counts())
    half = sum(models.build_model("vgg16", 0.5).layer_param_counts())
    assert half < full / 2.5


def test_resnet20_full_width_param_count_close_to_paper():
    """He et al. report ~0.27M params for CIFAR ResNet-20."""
    total = sum(models.build_model("resnet20", 1.0).layer_param_counts())
    assert 0.25e6 < total < 0.31e6


def test_table1_ppvs_are_legal():
    for model, stages_map in TABLE1_PPV.items():
        m = models.build_model(model, 0.25)
        for ns, ppv in stages_map.items():
            assert all(1 <= p < m.num_layers for p in ppv), (model, ppv)
            assert ns == 2 * len(ppv) + 2  # K registers -> 2K+2 stages

def test_manifest_configs_build():
    for name, cfg in MANIFEST.items():
        m = models.build_model(cfg["model"], cfg["width_mult"])
        assert all(1 <= p < m.num_layers for p in cfg["ppv"]), name


def test_resnet_early_layers_hold_bulk_of_flops():
    """Paper §6.3: first residual functions take >50% of runtime; our
    analytic FLOPs model must reproduce that profile for resnet20."""
    m = models.build_model("resnet20", 1.0)
    fl = m.flops_per_sample()
    # layers 1..7 (stem + first three blocks) vs total
    early = sum(fl[:7]); total = sum(fl)
    assert early / total > 0.35, early / total
