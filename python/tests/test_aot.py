"""AOT driver tests: meta.json schema, program I/O arity, HLO text shape,
and incremental-build behaviour."""

import json
import os
import tempfile

import pytest

from compile import aot, experiments, models, stages


def test_manifest_has_every_experiment_family():
    names = set(experiments.MANIFEST)
    for needed in ("lenet5_4s", "lenet5_10s", "alexnet_8s", "vgg16_10s",
                   "resnet20_4s", "resnet20_fine8", "resnet20_fine20",
                   "resnet20_slide19", "resnet20_hybrid", "resnet56_4s",
                   "resnet110_4s", "resnet224_4s", "resnet362_4s",
                   "resnet20_mem", "resnet362_mem", "quickstart_lenet"):
        assert needed in names, needed


def test_meta_schema_quickstart():
    cfg = experiments.MANIFEST["quickstart_lenet"]
    meta, model, parts, carries = aot.config_meta(cfg)
    assert meta["num_layers"] == 5
    assert len(meta["partitions"]) == len(cfg["ppv"]) + 1
    assert meta["partitions"][0]["carry_in"] == [[32, 28, 28, 1]]
    last = meta["partitions"][-1]
    assert "last" in last["programs"] and "last_eval" in last["programs"]
    assert sum(p["param_count"] for p in meta["partitions"]) == \
        sum(l["param_count"] for l in meta["layers"])
    # layer metadata drives the Table-6 memory model
    for l in meta["layers"]:
        assert l["carry_elems_per_sample"] > 0
        assert l["flops_per_sample"] >= 0


def test_meta_carry_chain_is_consistent():
    cfg = experiments.MANIFEST["resnet20_fine8"]
    meta, _, _, _ = aot.config_meta(cfg)
    parts = meta["partitions"]
    for a, b in zip(parts, parts[1:]):
        assert a["carry_out"] == b["carry_in"], (a["index"], b["index"])


def test_hlo_text_emission_and_incremental(tmp_path):
    cfg = dict(experiments.MANIFEST["quickstart_lenet"])
    digest = aot._source_digest()
    assert aot.lower_config(cfg, str(tmp_path), digest) == "built"
    cdir = tmp_path / cfg["name"]
    meta = json.loads((cdir / "meta.json").read_text())
    for part in meta["partitions"]:
        for prog in part["programs"].values():
            text = (cdir / prog).read_text()
            assert text.startswith("HloModule"), prog
            assert "ENTRY" in text
    # second run is a no-op
    assert aot.lower_config(cfg, str(tmp_path), digest) == "up-to-date"
    # source change forces rebuild
    assert aot.lower_config(cfg, str(tmp_path), "otherdigest") == "built"


def test_meta_only_config_writes_no_hlo(tmp_path):
    cfg = dict(experiments.MANIFEST["resnet20_mem"])
    aot.lower_config(cfg, str(tmp_path), "d")
    cdir = tmp_path / cfg["name"]
    assert (cdir / "meta.json").exists()
    assert not list(cdir.glob("*.hlo.txt"))


def test_program_arity_matches_meta():
    """The positional contract Rust relies on: count inputs/outputs."""
    cfg = experiments.MANIFEST["quickstart_lenet"]
    meta, model, parts, carries = aot.config_meta(cfg)
    import jax.numpy as jnp
    import numpy as np
    from compile.layers import init_value
    rng = np.random.default_rng(0)
    p0 = parts[0]
    params = [jnp.asarray(init_value(tuple(s["shape"]), s["init"],
                                     s["fan_in"], rng))
              for s in meta["partitions"][0]["params"]]
    state = [jnp.asarray(init_value(tuple(s["shape"]), s["init"], 0, rng))
             for s in meta["partitions"][0]["state"]]
    x = jnp.asarray(rng.normal(
        size=tuple(meta["partitions"][0]["carry_in"][0])).astype(np.float32))
    out = stages.make_fwd(p0)(*params, *state, jnp.int32(0), x)
    n_carry_out = len(meta["partitions"][0]["carry_out"])
    assert len(out) == n_carry_out + len(state)
    gouts = [jnp.ones(tuple(s), jnp.float32)
             for s in meta["partitions"][0]["carry_out"]]
    bout = stages.make_bwd(p0, n_carry_out)(
        *params, *state, jnp.int32(0), x, *gouts)
    assert len(bout) == 1 + len(params)  # gcarry_in + dparams
