"""Manifest of every AOT config the Rust benches/examples consume.

A config = (model, width_mult, PPV, batch). Names are stable identifiers:
the Rust side loads `artifacts/<name>/meta.json`. PPVs follow the paper's
Table 1 / §6.3; width_mult and batch implement the scaled experiment
protocol of DESIGN.md §4 (1-core CPU testbed).

Non-pipelined baselines need no dedicated config: the coordinator runs any
config's stage programs sequentially with immediate updates (K=0
semantics), bit-identical to an unpartitioned run (tested).
"""

# Table 1 — Pipeline Placement Vectors (paper).
TABLE1_PPV = {
    "lenet5": {4: (1,), 6: (1, 2), 8: (1, 2, 3), 10: (1, 2, 3, 4)},
    "alexnet": {4: (1,), 6: (1, 2), 8: (1, 2, 3)},
    "vgg16": {4: (2,), 6: (2, 4), 8: (2, 4, 7), 10: (2, 4, 7, 10)},
    "resnet20": {4: (7,), 6: (7, 13), 8: (7, 13, 19)},
}

# Table 7 — BKS_2 learning rates for the actual 4-stage pipelined runs.
TABLE7_BKS2_LR = {
    "resnet20": 0.1, "resnet56": 0.01, "resnet110": 0.001,
    "resnet224": 0.001, "resnet362": 0.001,
}


def _cfg(name, model, ppv, *, width=1.0, batch=64, meta_only=False):
    return {
        "name": name, "model": model, "ppv": tuple(ppv),
        "width_mult": width, "batch": batch, "meta_only": meta_only,
    }


def manifest():
    cfgs = []

    # --- Figure 5 / Table 2: 4/6/8/10-stage pipelining, four CNNs -------
    for model, stages in TABLE1_PPV.items():
        width = {"lenet5": 1.0, "alexnet": 0.25, "vgg16": 0.25,
                 "resnet20": 0.5}[model]
        batch = 64 if model == "lenet5" else 32
        for ns, ppv in stages.items():
            cfgs.append(_cfg(f"{model}_{ns}s", model, ppv,
                             width=width, batch=batch))

    # --- Table 3 / Fig 6 "Increasing Stages": fine-grained ResNet-20 ----
    # 8-stage = PPV (3,5,7); then a register after every 2 layers past 7.
    fine = [3, 5, 7]
    cfgs.append(_cfg("resnet20_fine8", "resnet20", tuple(fine),
                     width=0.5, batch=32))
    for extra in range(9, 20, 2):
        fine = fine + [extra]
        ns = 2 * len(fine) + 2
        cfgs.append(_cfg(f"resnet20_fine{ns}", "resnet20", tuple(fine),
                         width=0.5, batch=32))

    # --- Fig 6 "Sliding Stage": one register pair sliding through -------
    for p in (3, 5, 7, 9, 11, 13, 15, 17, 19):
        cfgs.append(_cfg(f"resnet20_slide{p}", "resnet20", (p,),
                         width=0.5, batch=32))

    # --- Table 4 / Fig 7: hybrid training, PPV (5,12,17) ----------------
    cfgs.append(_cfg("resnet20_hybrid", "resnet20", (5, 12, 17),
                     width=0.5, batch=32))

    # --- Table 5: 4-stage actual pipelining, ResNet-20/56/110 -----------
    # (paper also runs 224/362; those are meta-only here — the DES uses
    # their analytic cost model; see DESIGN.md §4.)
    cfgs.append(_cfg("resnet56_4s", "resnet56", (19,), width=0.5, batch=32))
    cfgs.append(_cfg("resnet110_4s", "resnet110", (37,), width=0.25, batch=32))
    cfgs.append(_cfg("resnet224_4s", "resnet224", (75,), width=0.25,
                     batch=32, meta_only=True))
    cfgs.append(_cfg("resnet362_4s", "resnet362", (121,), width=0.25,
                     batch=32, meta_only=True))

    # --- Table 6 memory model wants full-width shapes: meta-only --------
    for depth, p in ((20, 7), (56, 19), (110, 37), (224, 75), (362, 121)):
        cfgs.append(_cfg(f"resnet{depth}_mem", f"resnet{depth}", (p,),
                         width=1.0, batch=1, meta_only=True))

    # --- quickstart example: tiny & fast --------------------------------
    cfgs.append(_cfg("quickstart_lenet", "lenet5", (2,), width=1.0, batch=32))

    return {c["name"]: c for c in cfgs}


MANIFEST = manifest()
