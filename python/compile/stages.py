"""PPV -> per-stage programs (the L2 <-> L3 contract).

A config is (model, PPV, batch). The PPV = (p_1..p_K) follows the paper's
§3: a register pair after layer p_i creates K+1 forward stages, where
stage i holds layers p_{i-1}+1 .. p_i. For each partition we build four
pure jax functions with *flat* argument lists (the Rust runtime feeds
PJRT buffers positionally; meta.json records the ordering):

  fwd       (params.., state.., seed:i32, carry_in..)           -> (carry_out.., new_state..)
  bwd       (params.., state.., seed, carry_in.., gcarry_out..) -> (gcarry_in.., dparams..)
  fwd_eval  (params.., state.., carry_in..)                     -> (carry_out..)
  last      (params.., state.., seed, carry_in.., labels:i32[N])
              -> (loss, correct, gcarry_in.., dparams.., new_state..)
  last_eval (params.., state.., carry_in..)                     -> (logits,)

`bwd` recomputes the partition forward internally (jax.vjp) from the saved
carry_in — the paper's "intermediate activations" are exactly carry_in, so
the coordinator stores one tensor tuple per in-flight mini-batch and no
weight copies (the paper's memory claim, Table 6).
"""

import jax
import jax.numpy as jnp


class Partition:
    """One pipeline stage: a contiguous slice of model layers."""

    def __init__(self, model, index, lo, hi):
        """Layers lo..hi inclusive, 1-indexed (paper numbering)."""
        self.model = model
        self.index = index          # 1-based stage index
        self.lo, self.hi = lo, hi
        self.layers = model.layers[lo - 1: hi]
        self.param_specs = [s for l in self.layers for s in l.param_specs()]
        self.state_specs = [s for l in self.layers for s in l.state_specs()]
        self.param_names = [s[0] for s in self.param_specs]
        self.state_names = [s[0] for s in self.state_specs]

    def param_count(self):
        total = 0
        for _n, shape, _i, _f in self.param_specs:
            c = 1
            for d in shape:
                c *= d
            total += c
        return total

    def _apply(self, params, state, carry, *, train, seed):
        updates = dict(state)
        for layer in self.layers:
            carry, up = layer.apply(params, updates, carry,
                                    train=train, seed=seed)
            updates.update(up)
        return carry, updates


def split(model, ppv):
    """PPV -> list[Partition] (K+1 partitions)."""
    ppv = list(ppv)
    assert all(1 <= p < model.num_layers for p in ppv), \
        f"PPV {ppv} out of range for {model.name} ({model.num_layers} layers)"
    assert ppv == sorted(ppv) and len(set(ppv)) == len(ppv), \
        f"PPV must be strictly increasing: {ppv}"
    bounds = [0] + ppv + [model.num_layers]
    return [Partition(model, i + 1, bounds[i] + 1, bounds[i + 1])
            for i in range(len(bounds) - 1)]


def carry_shapes(model, ppv, batch):
    """Carry shapes entering each partition (index 0 = model input)."""
    after = model.carry_shapes_after(batch)
    shapes = [((batch,) + tuple(model.input_shape),)]
    for p in ppv:
        shapes.append(after[p - 1])
    return shapes


def _unflatten(part, args):
    np_, ns = len(part.param_names), len(part.state_names)
    params = dict(zip(part.param_names, args[:np_]))
    state = dict(zip(part.state_names, args[np_:np_ + ns]))
    return params, state, args[np_ + ns:]


def make_fwd(part, train=True):
    def fwd(*args):
        params, state, rest = _unflatten(part, args)
        seed, carry = rest[0], tuple(rest[1:])
        out, updates = part._apply(params, state, carry, train=train, seed=seed)
        new_state = tuple(updates[n] for n in part.state_names)
        return tuple(out) + new_state
    return fwd


def make_fwd_eval(part):
    def fwd_eval(*args):
        params, state, carry = _unflatten(part, args)
        out, _ = part._apply(params, state, tuple(carry), train=False,
                             seed=jnp.int32(0))
        return tuple(out)
    return fwd_eval


def make_bwd(part, n_carry_out):
    def bwd(*args):
        params, state, rest = _unflatten(part, args)
        seed = rest[0]
        carry_in = tuple(rest[1: len(rest) - n_carry_out])
        gout = tuple(rest[len(rest) - n_carry_out:])

        plist = tuple(params[n] for n in part.param_names)

        def core(plist_, carry_):
            p = dict(zip(part.param_names, plist_))
            out, _ = part._apply(p, state, carry_, train=True, seed=seed)
            return tuple(out)

        _, vjp = jax.vjp(core, plist, carry_in)
        gp, gc = vjp(gout)
        return tuple(gc) + tuple(gp)
    return bwd


def _loss_and_metrics(logits, labels):
    logz = jax.nn.log_softmax(logits)
    n = logits.shape[0]
    nll = -logz[jnp.arange(n), labels]
    loss = jnp.mean(nll)
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, correct


def make_last(part):
    """Fused FS_{K+1}+BKS_1 program: fwd + loss + bwd in one executable
    (the paper co-locates them on one accelerator; staleness 0)."""

    def last(*args):
        params, state, rest = _unflatten(part, args)
        seed = rest[0]
        carry_in = tuple(rest[1:-1])
        labels = rest[-1]
        plist = tuple(params[n] for n in part.param_names)

        def core(plist_, carry_):
            p = dict(zip(part.param_names, plist_))
            out, updates = part._apply(p, state, carry_, train=True, seed=seed)
            loss, correct = _loss_and_metrics(out[0], labels)
            new_state = tuple(updates[n] for n in part.state_names)
            return loss, (correct, new_state)

        loss, vjp, (correct, new_state) = jax.vjp(
            core, plist, carry_in, has_aux=True)
        gp, gc = vjp(jnp.float32(1.0))
        return (loss, correct) + tuple(gc) + tuple(gp) + tuple(new_state)
    return last


def make_last_eval(part):
    def last_eval(*args):
        params, state, carry = _unflatten(part, args)
        out, _ = part._apply(params, state, tuple(carry), train=False,
                             seed=jnp.int32(0))
        return (out[0],)
    return last_eval


def full_forward(model, params, state, x, *, train=False, seed=0):
    """Reference whole-model forward (tests: composed stages == this)."""
    carry = (x,)
    updates = dict(state)
    for layer in model.layers:
        carry, up = layer.apply(params, updates, carry, train=train,
                                seed=jnp.int32(seed))
        updates.update(up)
    return carry[0], updates
