"""Model zoo: the paper's four CNN families, as flat lists of Layers.

Layer numbering matches the paper's PPV convention (Table 1): a pipeline
register pair may be placed after any layer 1..L-1. `width_mult` scales
channel widths so that the experiment profile can trade fidelity for
wall-clock on the 1-core CPU testbed (DESIGN.md §4); `width_mult=1.0` is
the paper-faithful architecture.
"""

from .layers import (Act, BatchNorm, Conv, Dense, Dropout, Flatten,
                     GlobalAvgPool, Layer, MaxPool, ResEnd, ResStart)


class Model:
    def __init__(self, name, layers, input_shape, num_classes, dataset):
        self.name = name
        self.layers = layers          # list[Layer], 1-indexed as paper: layers[i-1]
        self.input_shape = input_shape  # (H, W, C)
        self.num_classes = num_classes
        self.dataset = dataset        # "mnist" | "cifar10"

    @property
    def num_layers(self):
        return len(self.layers)

    def layer_param_counts(self):
        return [l.param_count() for l in self.layers]

    def carry_shapes_after(self, batch):
        """Carry shapes after each layer (index i -> after layer i+1)."""
        shapes = ((batch,) + tuple(self.input_shape),)
        out = []
        for layer in self.layers:
            shapes = layer.out_shapes(shapes)
            out.append(shapes)
        return out

    def flops_per_sample(self):
        """Forward FLOPs per layer for one sample (perfsim cost model)."""
        shapes = ((1,) + tuple(self.input_shape),)
        out = []
        for layer in self.layers:
            out.append(layer.flops_per_sample(shapes))
            shapes = layer.out_shapes(shapes)
        return out


def _w(c, mult):
    """Scale a channel width, keeping it a positive multiple of 4."""
    if mult >= 1.0:
        return int(round(c * mult))
    return max(4, int(round(c * mult / 4)) * 4)


def lenet5(width_mult=1.0, num_classes=10):
    """LeNet-5 on MNIST (5 layers), tanh activations as in LeCun'98."""
    m = width_mult
    c1, c2 = _w(6, m), _w(16, m)
    f1, f2 = _w(120, m), _w(84, m)
    # 28x28 -> SAME conv -> pool 14x14 -> VALID 5x5 conv -> 10x10 -> pool 5x5
    flat = 5 * 5 * c2
    layers = [
        Layer("l1", [Conv("conv1", 1, c1, 5, 1, "SAME"), Act("act1", "tanh"),
                     MaxPool("pool1", 2)]),
        Layer("l2", [Conv("conv2", c1, c2, 5, 1, "VALID"), Act("act2", "tanh"),
                     MaxPool("pool2", 2)]),
        Layer("l3", [Flatten("flat"), Dense("fc1", flat, f1, "tanh")]),
        Layer("l4", [Dense("fc2", f1, f2, "tanh")]),
        Layer("l5", [Dense("fc3", f2, num_classes)]),
    ]
    return Model("lenet5", layers, (28, 28, 1), num_classes, "mnist")


def alexnet(width_mult=1.0, num_classes=10):
    """AlexNet adapted to CIFAR-10 (8 layers: 5 conv + 3 fc)."""
    m = width_mult
    c = [_w(64, m), _w(192, m), _w(384, m), _w(256, m), _w(256, m)]
    f = [_w(1024, m), _w(512, m)]
    flat = 4 * 4 * c[4]  # 32 -> pool -> 16 -> pool -> 8 -> pool -> 4
    layers = [
        Layer("l1", [Conv("conv1", 3, c[0], 5), Act("a1"), MaxPool("p1", 2)]),
        Layer("l2", [Conv("conv2", c[0], c[1], 5), Act("a2"), MaxPool("p2", 2)]),
        Layer("l3", [Conv("conv3", c[1], c[2], 3), Act("a3")]),
        Layer("l4", [Conv("conv4", c[2], c[3], 3), Act("a4")]),
        Layer("l5", [Conv("conv5", c[3], c[4], 3), Act("a5"), MaxPool("p5", 2)]),
        Layer("l6", [Flatten("flat"), Dropout("do6", 0.5, salt=6),
                     Dense("fc6", flat, f[0], "relu")]),
        Layer("l7", [Dropout("do7", 0.5, salt=7), Dense("fc7", f[0], f[1], "relu")]),
        Layer("l8", [Dense("fc8", f[1], num_classes)]),
    ]
    return Model("alexnet", layers, (32, 32, 3), num_classes, "cifar10")


_VGG_PLANS = {
    # (conv widths per layer, pool after these layer indices (1-based))
    "vgg11": ([64, 128, 256, 256, 512, 512, 512, 512],
              {1, 2, 4, 6, 8}),
    "vgg16": ([64, 64, 128, 128, 256, 256, 256, 512, 512, 512, 512, 512, 512],
              {2, 4, 7, 10, 13}),
}


def vgg(kind="vgg16", width_mult=1.0, num_classes=10):
    """VGG on CIFAR-10 with BN + dropout (paper Appendix A). vgg16 has 16
    paper-layers: 13 conv + 2 fc(+dropout) + classifier."""
    widths, pools = _VGG_PLANS[kind]
    m = width_mult
    layers = []
    cin = 3
    for i, wdt in enumerate(widths, start=1):
        c = _w(wdt, m)
        ops = [Conv(f"conv{i}", cin, c, 3),
               BatchNorm(f"bn{i}", c), Act(f"a{i}")]
        if i in pools:
            ops.append(MaxPool(f"p{i}", 2))
        layers.append(Layer(f"l{i}", ops))
        cin = c
    # After 5 pools: 32 / 32 = 1 -> flat = cin
    nconv = len(widths)
    fc = _w(512, m)
    layers.append(Layer(f"l{nconv+1}",
                        [Flatten("flat"), Dropout("do1", 0.5, salt=1),
                         Dense("fc1", cin, fc, "relu")]))
    layers.append(Layer(f"l{nconv+2}",
                        [Dropout("do2", 0.5, salt=2),
                         Dense("fc2", fc, fc, "relu")]))
    layers.append(Layer(f"l{nconv+3}", [Dense("fc3", fc, num_classes)]))
    return Model(kind, layers, (32, 32, 3), num_classes, "cifar10")


def resnet(depth=20, width_mult=1.0, num_classes=10):
    """CIFAR ResNet (He et al. 2016): depth = 6m+2, paper layer numbering:
    layer 1 = stem conv, layers 2..6m+1 = block convs, layer 6m+2 = head.

    A pipeline register may fall *inside* a residual block (between its two
    conv layers): the skip tensor then travels through the register as part
    of the carry (see layers.ResStart/ResEnd). Shortcuts that change shape
    use a 1x1 projection + BN (option B); the paper's akamaster baseline
    uses option A — a documented substitution (DESIGN.md §4).
    """
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6m+2"
    mblocks = (depth - 2) // 6
    m = width_mult
    widths = [_w(16, m), _w(32, m), _w(64, m)]
    layers = [
        Layer("l1", [Conv("conv0", 3, widths[0], 3, bias=False),
                     BatchNorm("bn0", widths[0]), Act("a0")]),
    ]
    cin = widths[0]
    lnum = 2
    for g, c in enumerate(widths):
        for j in range(mblocks):
            stride = 2 if (g > 0 and j == 0) else 1
            tag = f"g{g}b{j}"
            layers.append(Layer(
                f"l{lnum}",
                [ResStart(f"{tag}/start"),
                 Conv(f"{tag}/conv1", cin, c, 3, stride, bias=False),
                 BatchNorm(f"{tag}/bn1", c), Act(f"{tag}/a1")]))
            lnum += 1
            layers.append(Layer(
                f"l{lnum}",
                [Conv(f"{tag}/conv2", c, c, 3, 1, bias=False),
                 BatchNorm(f"{tag}/bn2", c),
                 ResEnd(f"{tag}/end", cin, c, stride),
                 Act(f"{tag}/a2")]))
            lnum += 1
            cin = c
    layers.append(Layer(f"l{lnum}",
                        [GlobalAvgPool("gap"), Flatten("flat"),
                         Dense("fc", cin, num_classes)]))
    return Model(f"resnet{depth}", layers, (32, 32, 3), num_classes, "cifar10")


def build_model(name, width_mult=1.0, num_classes=10):
    """Registry entry point used by aot.py and tests."""
    if name == "lenet5":
        return lenet5(width_mult, num_classes)
    if name == "alexnet":
        return alexnet(width_mult, num_classes)
    if name in _VGG_PLANS:
        return vgg(name, width_mult, num_classes)
    if name.startswith("resnet"):
        return resnet(int(name[len("resnet"):]), width_mult, num_classes)
    raise ValueError(f"unknown model {name!r}")
