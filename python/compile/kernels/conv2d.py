"""Pallas convolution kernel — the L1 compute hot-spot.

Hardware adaptation (paper -> TPU, see DESIGN.md §3): the paper's hot-spot
is cuDNN convolution on GTX1060 GPUs. On a TPU the same insight (keep the
MXU busy with large contractions, stage tiles through fast scratchpad
memory) is expressed as an *im2col-free blocked matmul*: for each (kh, kw)
tap of the filter, a strided slice of the input tile is contracted against
the (Cin, Cout) slice of the filter on the MXU, accumulating in VMEM. The
BlockSpec grid tiles over (batch, out-channel) so each kernel instance
holds one input tile and one filter tile in VMEM — the role threadblock
tiling plays in the CUDA formulation.

interpret=True is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO so
the whole model remains executable from the Rust runtime. The blocking
structure is still the real-TPU structure; DESIGN.md §Perf estimates VMEM
footprint and MXU utilization from the BlockSpecs.

Gradients: `conv2d` carries a jax.custom_vjp whose backward rule is the
vjp of the pure-jnp reference (`ref.conv2d_ref`) — correct by construction
and fusable by XLA.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import conv2d_ref, explicit_padding

# Block sizes. On a real TPU these target an 8x128-lane VPU layout and a
# 128x128 MXU; Cout is tiled to at most MXU width, batch to keep the input
# tile within a VMEM budget (see vmem_footprint_bytes below).
_BLOCK_OC = 128
_BLOCK_N = 32


def _pick_block(total, target):
    """Largest divisor of `total` that is <= target (>=1)."""
    best = 1
    for d in range(1, total + 1):
        if total % d == 0 and d <= target:
            best = d
    return best


def _conv_kernel(x_ref, w_ref, o_ref, *, kh, kw, oh, ow, stride):
    """One (batch-tile, out-channel-tile) grid cell.

    x_ref: (BN, PH, PW, Cin) pre-padded input tile in VMEM
    w_ref: (KH, KW, Cin, BOC) filter tile in VMEM
    o_ref: (BN, OH, OW, BOC) output tile in VMEM
    """
    x = x_ref[...]
    bn = x.shape[0]
    cin = x.shape[3]
    acc = jnp.zeros((bn * oh * ow, o_ref.shape[3]), dtype=jnp.float32)
    # Accumulate one MXU contraction per filter tap: (BN*OH*OW, Cin) @
    # (Cin, BOC). Taps are unrolled at trace time (kh, kw are Python ints).
    for i in range(kh):
        for j in range(kw):
            xs = x[:, i : i + (oh - 1) * stride + 1 : stride,
                     j : j + (ow - 1) * stride + 1 : stride, :]
            xs = xs.reshape(bn * oh * ow, cin)
            wt = w_ref[i, j, :, :]
            acc = acc + jnp.dot(xs, wt, preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(bn, oh, ow, o_ref.shape[3])


# Per-core VMEM budget for one kernel instance (TPU ~16 MiB; leave head
# room for double-buffering). The §Perf pass found full-width VGG conv1 at
# batch 128 exceeding 16 MiB with a fixed 32-sample batch tile; the batch
# tile now shrinks adaptively until the instance fits.
_VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _instance_bytes(bn, ph, pw, cin, kh, kw, oh, ow, boc, dtype_bytes=4):
    x_tile = bn * ph * pw * cin
    w_tile = kh * kw * cin * boc
    o_tile = bn * oh * ow * boc
    acc = bn * oh * ow * boc  # f32 accumulator
    return (x_tile + w_tile + o_tile + acc) * dtype_bytes


def conv2d_pallas(x, w, *, stride=1, padding="SAME",
                  vmem_budget=_VMEM_BUDGET_BYTES):
    """Forward convolution through the Pallas kernel (no bias).

    x: f32[N, H, W, Cin], w: f32[KH, KW, Cin, Cout] -> f32[N, OH, OW, Cout]
    """
    n, h, wdim, cin = x.shape
    kh, kw, wcin, cout = w.shape
    assert cin == wcin, f"Cin mismatch: {cin} vs {wcin}"
    (plo, phi), (qlo, qhi) = explicit_padding(
        padding, kh, kw, stride, stride, h=h, w=wdim)
    xp = jnp.pad(x, ((0, 0), (plo, phi), (qlo, qhi), (0, 0)))
    ph, pw = xp.shape[1], xp.shape[2]
    oh = (ph - kh) // stride + 1
    ow = (pw - kw) // stride + 1

    boc = _pick_block(cout, _BLOCK_OC)
    # Adaptive batch tile (§Perf): largest divisor of n, at most _BLOCK_N,
    # whose instance footprint fits the VMEM budget.
    bn = _pick_block(n, _BLOCK_N)
    while bn > 1 and _instance_bytes(bn, ph, pw, cin, kh, kw, oh, ow, boc) > vmem_budget:
        bn = _pick_block(n, bn - 1)
    grid = (n // bn, cout // boc)

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, oh=oh, ow=ow, stride=stride)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, ph, pw, cin), lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, boc), lambda b, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((bn, oh, ow, boc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.float32),
        interpret=True,
    )(xp, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def conv2d(x, w, stride=1, padding="SAME"):
    """Differentiable convolution: Pallas forward, reference-vjp backward."""
    return conv2d_pallas(x, w, stride=stride, padding=padding)


def _conv2d_fwd(x, w, stride, padding):
    return conv2d_pallas(x, w, stride=stride, padding=padding), (x, w)


def _conv2d_bwd(stride, padding, res, g):
    x, w = res
    _, vjp = jax.vjp(
        lambda x_, w_: conv2d_ref(x_, w_, stride=stride, padding=padding),
        x, w)
    return vjp(g)


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


def vmem_footprint_bytes(n, h, w, cin, kh, kw, cout, *, stride=1,
                         padding="SAME", dtype_bytes=4,
                         vmem_budget=_VMEM_BUDGET_BYTES):
    """Estimated VMEM bytes held by one kernel instance (in + filter + out
    + accumulator), with the adaptive batch tile applied.

    Used by the §Perf analysis (DESIGN.md / EXPERIMENTS.md): on a real TPU
    the sum must stay under ~16 MiB/core for the schedule to be valid.
    """
    (plo, phi), (qlo, qhi) = explicit_padding(
        padding, kh, kw, stride, stride, h=h, w=w)
    ph, pw = h + plo + phi, w + qlo + qhi
    oh = (ph - kh) // stride + 1
    ow = (pw - kw) // stride + 1
    boc = _pick_block(cout, _BLOCK_OC)
    bn = _pick_block(n, _BLOCK_N)
    while bn > 1 and _instance_bytes(bn, ph, pw, cin, kh, kw, oh, ow, boc,
                                     dtype_bytes) > vmem_budget:
        bn = _pick_block(n, bn - 1)
    return _instance_bytes(bn, ph, pw, cin, kh, kw, oh, ow, boc, dtype_bytes)


def mxu_utilization_estimate(cin, cout):
    """Fraction of the 128x128 MXU a single tap-contraction can fill.

    The contraction is (BN*OH*OW, Cin) @ (Cin, BOC): the K dimension is
    Cin and the N dimension is min(Cout, 128). Early CNN layers with tiny
    Cin underfill the MXU K dimension — the classic conv-on-MXU effect the
    im2col-per-tap schedule mitigates by keeping M large.
    """
    k_fill = min(cin, 128) / 128.0
    n_fill = min(cout, 128) / 128.0
    return k_fill * n_fill
