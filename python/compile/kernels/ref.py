"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth for correctness: pytest compares every Pallas
kernel against these implementations (see python/tests/test_kernels.py),
and the custom_vjp backward rules of the kernels are *derived* from these
references via jax.vjp, so gradients are correct by construction.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, b=None, *, stride=1, padding="SAME"):
    """NHWC x HWIO -> NHWC convolution.

    Args:
      x: f32[N, H, W, Cin]
      w: f32[KH, KW, Cin, Cout]
      b: optional f32[Cout]
      stride: int spatial stride (same in H and W)
      padding: "SAME" | "VALID" | explicit ((lo,hi),(lo,hi))
    """
    dn = lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    y = lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=dn,
    )
    if b is not None:
        y = y + b
    return y


def matmul_ref(x, w, b=None, *, activation="none"):
    """Fused dense layer reference: act(x @ w + b).

    Args:
      x: f32[M, K]
      w: f32[K, N]
      b: optional f32[N]
      activation: "none" | "relu" | "tanh"
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def explicit_padding(padding, kh, kw, sh=1, sw=1, h=None, w=None):
    """Resolve "SAME"/"VALID"/explicit padding into ((lo,hi),(lo,hi)).

    For "SAME" the input spatial dims (h, w) and strides are required to
    match XLA's semantics: total pad = max((ceil(d/s)-1)*s + k - d, 0).
    """
    if padding == "VALID":
        return ((0, 0), (0, 0))
    if padding == "SAME":
        assert h is not None and w is not None

        def same(d, k, s):
            out = -(-d // s)  # ceil div
            total = max((out - 1) * s + k - d, 0)
            return (total // 2, total - total // 2)

        return (same(h, kh, sh), same(w, kw, sw))
    return tuple((int(lo), int(hi)) for lo, hi in padding)
