"""Pallas fused dense kernel: act(x @ w + b).

The dense layers of LeNet/AlexNet/VGG heads are matmuls small enough that
the win on a real TPU is *fusion* (bias add + activation applied while the
accumulator tile is still in VMEM) rather than tiling depth. The grid
tiles (M, N); K is kept whole — for every dense layer in the model zoo
K <= 4096, well within a VMEM tile.

Same AOT caveats as conv2d.py: interpret=True so the lowered HLO runs on
the CPU PJRT client; custom_vjp backward comes from the jnp reference.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import matmul_ref

_BLOCK_M = 128
_BLOCK_N = 128


def _pick_block(total, target):
    best = 1
    for d in range(1, total + 1):
        if total % d == 0 and d <= target:
            best = d
    return best


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "tanh":
        acc = jnp.tanh(acc)
    o_ref[...] = acc


def dense_pallas(x, w, b, *, activation="none"):
    """x: f32[M, K], w: f32[K, N], b: f32[N] -> f32[M, N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    bm = _pick_block(m, _BLOCK_M)
    bn = _pick_block(n, _BLOCK_N)
    grid = (m // bm, n // bn)
    kernel = functools.partial(_dense_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="none"):
    """Differentiable fused dense: Pallas forward, reference-vjp backward."""
    return dense_pallas(x, w, b, activation=activation)


def _dense_fwd(x, w, b, activation):
    return dense_pallas(x, w, b, activation=activation), (x, w, b)


def _dense_bwd(activation, res, g):
    x, w, b = res
    _, vjp = jax.vjp(
        lambda x_, w_, b_: matmul_ref(x_, w_, b_, activation=activation),
        x, w, b)
    return vjp(g)


dense.defvjp(_dense_fwd, _dense_bwd)
