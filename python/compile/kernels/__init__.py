"""L1: Pallas kernels for the compute hot-spots (build-time only)."""

from .conv2d import conv2d, conv2d_pallas, mxu_utilization_estimate, vmem_footprint_bytes
from .matmul import dense, dense_pallas
from .ref import conv2d_ref, explicit_padding, matmul_ref

__all__ = [
    "conv2d", "conv2d_pallas", "conv2d_ref",
    "dense", "dense_pallas", "matmul_ref",
    "explicit_padding", "vmem_footprint_bytes", "mxu_utilization_estimate",
]
