"""AOT lowering driver: manifest configs -> artifacts/<name>/{*.hlo.txt,meta.json}.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (the version
the published `xla` 0.1.6 crate links) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs only here, at build time. `make artifacts` is incremental: a
config is skipped when its meta.json already records the same build key
(model/ppv/width/batch + source digest).

Usage:
  python -m compile.aot --all [--force] [--out ../artifacts]
  python -m compile.aot --config resnet20_4s ...
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import experiments, models, stages


def to_hlo_text(fn, arg_specs):
    """Lower a jittable fn at the given ShapeDtypeStructs to HLO text.

    keep_unused=True: the Rust runtime feeds buffers positionally per
    meta.json, so arguments that a particular partition happens not to use
    (e.g. the dropout seed in a dropout-free stage, BN state in bwd) must
    stay in the entry signature.
    """
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


# Explicit artifact-schema version: bump when a compile-path change alters
# the *lowered HLO or meta.json* of existing configs (program signatures,
# layer math, stage splitting). Non-semantic kernel/API refactors need no
# bump, so `make artifacts` stays a no-op. (A file-content digest was used
# initially but forces whole-tree re-lowering on every comment edit;
# hashlib retained for build_key stability of the config payload itself.)
ARTIFACT_SCHEMA_VERSION = "2"


def _source_digest():
    """Build-key version component (see ARTIFACT_SCHEMA_VERSION)."""
    return hashlib.sha256(ARTIFACT_SCHEMA_VERSION.encode()).hexdigest()[:16]


def build_key(cfg, digest):
    return json.dumps({**{k: cfg[k] for k in
                          ("model", "ppv", "width_mult", "batch", "meta_only")},
                       "src": digest}, sort_keys=True, default=list)


def config_meta(cfg):
    """meta.json payload (everything the Rust side needs)."""
    model = models.build_model(cfg["model"], cfg["width_mult"])
    batch = cfg["batch"]
    ppv = list(cfg["ppv"])
    parts = stages.split(model, ppv)
    carries = stages.carry_shapes(model, ppv, batch)
    after = model.carry_shapes_after(batch)
    flops = model.flops_per_sample()

    layers_meta = []
    for i, layer in enumerate(model.layers):
        carry_elems = sum(
            int(jnp.prod(jnp.array(s[1:]))) for s in after[i])
        layers_meta.append({
            "name": layer.name,
            "param_count": layer.param_count(),
            "carry_elems_per_sample": carry_elems,
            "flops_per_sample": int(flops[i]),
        })

    parts_meta = []
    for i, part in enumerate(parts):
        is_last = i == len(parts) - 1
        programs = (
            {"last": f"stage{part.index}_last.hlo.txt",
             "last_eval": f"stage{part.index}_last_eval.hlo.txt"}
            if is_last else
            {"fwd": f"stage{part.index}_fwd.hlo.txt",
             "bwd": f"stage{part.index}_bwd.hlo.txt",
             "fwd_eval": f"stage{part.index}_fwd_eval.hlo.txt"})
        parts_meta.append({
            "index": part.index,
            "layer_lo": part.lo, "layer_hi": part.hi,
            "param_count": part.param_count(),
            "params": [{"name": n, "shape": list(s), "init": init,
                        "fan_in": fi}
                       for n, s, init, fi in part.param_specs],
            "state": [{"name": n, "shape": list(s), "init": init}
                      for n, s, init in part.state_specs],
            "carry_in": [list(s) for s in carries[i]],
            "carry_out": [list(s) for s in carries[i + 1]] if not is_last
                         else [[batch, model.num_classes]],
            "programs": programs,
        })

    return {
        "config": cfg["name"],
        "model": cfg["model"],
        "width_mult": cfg["width_mult"],
        "batch": batch,
        "dataset": model.dataset,
        "input_shape": list(model.input_shape),
        "num_classes": model.num_classes,
        "num_layers": model.num_layers,
        "ppv": ppv,
        "meta_only": cfg["meta_only"],
        "layers": layers_meta,
        "partitions": parts_meta,
    }, model, parts, carries


def lower_config(cfg, outdir, digest, force=False):
    cdir = os.path.join(outdir, cfg["name"])
    metapath = os.path.join(cdir, "meta.json")
    key = build_key(cfg, digest)
    if not force and os.path.exists(metapath):
        with open(metapath) as f:
            old = json.load(f)
        if old.get("build_key") == key:
            return "up-to-date"
    os.makedirs(cdir, exist_ok=True)

    meta, model, parts, carries = config_meta(cfg)
    meta["build_key"] = key

    if not cfg["meta_only"]:
        seed = jax.ShapeDtypeStruct((), jnp.int32)
        labels = jax.ShapeDtypeStruct((cfg["batch"],), jnp.int32)
        for i, part in enumerate(parts):
            pspecs = [_f32(s) for _n, s, _i, _f in part.param_specs]
            sspecs = [_f32(s) for _n, s, _i in part.state_specs]
            cin = [_f32(s) for s in carries[i]]
            is_last = i == len(parts) - 1
            pm = meta["partitions"][i]["programs"]
            if is_last:
                _emit(cdir, pm["last"], stages.make_last(part),
                      pspecs + sspecs + [seed] + cin + [labels])
                _emit(cdir, pm["last_eval"], stages.make_last_eval(part),
                      pspecs + sspecs + cin)
            else:
                cout = [_f32(s) for s in carries[i + 1]]
                _emit(cdir, pm["fwd"], stages.make_fwd(part, train=True),
                      pspecs + sspecs + [seed] + cin)
                _emit(cdir, pm["bwd"], stages.make_bwd(part, len(cout)),
                      pspecs + sspecs + [seed] + cin + cout)
                _emit(cdir, pm["fwd_eval"], stages.make_fwd_eval(part),
                      pspecs + sspecs + cin)

    with open(metapath, "w") as f:
        json.dump(meta, f, indent=1)
    return "built"


def _emit(cdir, fname, fn, specs):
    text = to_hlo_text(fn, specs)
    with open(os.path.join(cdir, fname), "w") as f:
        f.write(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", action="append", default=[])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "..", "artifacts"))
    args = ap.parse_args()

    names = (list(experiments.MANIFEST) if args.all or not args.config
             else args.config)
    digest = _source_digest()
    for name in names:
        cfg = experiments.MANIFEST.get(name)
        if cfg is None:
            sys.exit(f"unknown config {name!r}; known: "
                     f"{', '.join(sorted(experiments.MANIFEST))}")
        status = lower_config(cfg, args.out, digest, force=args.force)
        print(f"[aot] {name}: {status}", flush=True)


if __name__ == "__main__":
    main()
