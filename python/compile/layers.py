"""L2 layer zoo: pure-functional units grouped into paper-numbered layers.

The paper inserts pipeline registers "after layer p" (PPV, §3), so the
model is a flat list of `Layer`s, each a short sequence of atomic `Op`s.
Stage boundaries are only allowed at layer boundaries; the tensor tuple
that crosses a boundary is the *carry*.

Carry convention: a tuple of arrays. Every op transforms carry[0] and
passes the rest through, except the residual markers:
  * ResStart duplicates carry[0] onto the carry as the skip value;
  * ResEnd pops the skip, applies the shortcut, and adds it.
This lets a pipeline register fall *inside* a residual block (the paper's
fine-grained ResNet-20 experiments, Table 3, need cuts at every layer):
the skip tensor simply becomes part of the carry crossing the register.

State (BN running stats) is functional: apply() returns the updated state
dict; the Rust coordinator owns the authoritative copy.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import conv2d, dense


# ---------------------------------------------------------------------------
# Atomic ops
# ---------------------------------------------------------------------------

class Op:
    """An atomic operation inside a Layer."""

    name = "op"

    def param_specs(self):
        """[(name, shape, init, fan_in)] — init in {he, glorot, zeros, ones}."""
        return []

    def state_specs(self):
        """[(name, shape, init)] — init in {zeros, ones}."""
        return []

    def apply(self, params, state, carry, *, train, seed):
        """-> (carry', state_updates: dict)."""
        raise NotImplementedError

    def out_shapes(self, shapes):
        """Carry shapes out given carry shapes in (shapes exclude batch? no:
        full shapes including batch)."""
        raise NotImplementedError

    def flops_per_sample(self, shapes):
        """Approximate forward FLOPs for one sample (used by perfsim)."""
        return 0


def _p(op, pname):
    return f"{op.name}/{pname}"


class Conv(Op):
    """2D convolution (Pallas kernel) + optional bias."""

    def __init__(self, name, cin, cout, ksize, stride=1, padding="SAME",
                 bias=True):
        self.name = name
        self.cin, self.cout, self.k = cin, cout, ksize
        self.stride, self.padding, self.bias = stride, padding, bias

    def param_specs(self):
        specs = [(_p(self, "w"), (self.k, self.k, self.cin, self.cout),
                  "he", self.k * self.k * self.cin)]
        if self.bias:
            specs.append((_p(self, "b"), (self.cout,), "zeros", 0))
        return specs

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        y = conv2d(x, params[_p(self, "w")], self.stride, self.padding)
        if self.bias:
            y = y + params[_p(self, "b")]
        return (y,) + carry[1:], {}

    def out_shapes(self, shapes):
        n, h, w, _ = shapes[0]
        if self.padding == "SAME":
            oh = -(-h // self.stride)
            ow = -(-w // self.stride)
        else:  # VALID
            oh = (h - self.k) // self.stride + 1
            ow = (w - self.k) // self.stride + 1
        return ((n, oh, ow, self.cout),) + shapes[1:]

    def flops_per_sample(self, shapes):
        (_, oh, ow, _), = self.out_shapes(shapes)[:1]
        return 2 * oh * ow * self.k * self.k * self.cin * self.cout


class BatchNorm(Op):
    """Batch normalization with running statistics (momentum 0.9)."""

    def __init__(self, name, c, momentum=0.9, eps=1e-5):
        self.name, self.c = name, c
        self.momentum, self.eps = momentum, eps

    def param_specs(self):
        return [(_p(self, "gamma"), (self.c,), "ones", 0),
                (_p(self, "beta"), (self.c,), "zeros", 0)]

    def state_specs(self):
        return [(_p(self, "mean"), (self.c,), "zeros"),
                (_p(self, "var"), (self.c,), "ones")]

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        gamma, beta = params[_p(self, "gamma")], params[_p(self, "beta")]
        if train:
            axes = tuple(range(x.ndim - 1))
            mean = jnp.mean(x, axis=axes)
            var = jnp.var(x, axis=axes)
            m = self.momentum
            updates = {
                _p(self, "mean"): m * state[_p(self, "mean")] + (1 - m) * mean,
                _p(self, "var"): m * state[_p(self, "var")] + (1 - m) * var,
            }
        else:
            mean, var = state[_p(self, "mean")], state[_p(self, "var")]
            updates = {}
        y = (x - mean) * lax.rsqrt(var + self.eps) * gamma + beta
        return (y,) + carry[1:], updates

    def out_shapes(self, shapes):
        return shapes

    def flops_per_sample(self, shapes):
        n = 1
        for d in shapes[0][1:]:
            n *= d
        return 4 * n


class Act(Op):
    """Elementwise activation."""

    def __init__(self, name, kind="relu"):
        assert kind in ("relu", "tanh")
        self.name, self.kind = name, kind

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        y = jnp.maximum(x, 0.0) if self.kind == "relu" else jnp.tanh(x)
        return (y,) + carry[1:], {}

    def out_shapes(self, shapes):
        return shapes

    def flops_per_sample(self, shapes):
        n = 1
        for d in shapes[0][1:]:
            n *= d
        return n


class MaxPool(Op):
    def __init__(self, name, k=2, stride=None):
        self.name, self.k = name, k
        self.stride = stride or k

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        y = lax.reduce_window(
            x, -jnp.inf, lax.max,
            (1, self.k, self.k, 1), (1, self.stride, self.stride, 1), "VALID")
        return (y,) + carry[1:], {}

    def out_shapes(self, shapes):
        n, h, w, c = shapes[0]
        oh = (h - self.k) // self.stride + 1
        ow = (w - self.k) // self.stride + 1
        return ((n, oh, ow, c),) + shapes[1:]

    def flops_per_sample(self, shapes):
        (_, oh, ow, c), = self.out_shapes(shapes)[:1]
        return oh * ow * c * self.k * self.k


class GlobalAvgPool(Op):
    def __init__(self, name):
        self.name = name

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        return (jnp.mean(x, axis=(1, 2)),) + carry[1:], {}

    def out_shapes(self, shapes):
        n, h, w, c = shapes[0]
        return ((n, c),) + shapes[1:]

    def flops_per_sample(self, shapes):
        n, h, w, c = shapes[0]
        return h * w * c


class Flatten(Op):
    def __init__(self, name):
        self.name = name

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        return (x.reshape(x.shape[0], -1),) + carry[1:], {}

    def out_shapes(self, shapes):
        n = shapes[0][0]
        f = 1
        for d in shapes[0][1:]:
            f *= d
        return ((n, f),) + shapes[1:]


class Dense(Op):
    """Fully connected layer (Pallas fused kernel)."""

    def __init__(self, name, din, dout, act="none"):
        self.name, self.din, self.dout, self.act = name, din, dout, act

    def param_specs(self):
        return [(_p(self, "w"), (self.din, self.dout), "glorot", self.din),
                (_p(self, "b"), (self.dout,), "zeros", 0)]

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        y = dense(x, params[_p(self, "w")], params[_p(self, "b")], self.act)
        return (y,) + carry[1:], {}

    def out_shapes(self, shapes):
        return ((shapes[0][0], self.dout),) + shapes[1:]

    def flops_per_sample(self, shapes):
        return 2 * self.din * self.dout


class Dropout(Op):
    """Inverted dropout; the mask is derived from the per-batch seed, so
    the vjp recomputation in the backward stage reproduces it exactly."""

    def __init__(self, name, rate, salt=0):
        self.name, self.rate = name, rate
        self.salt = salt

    def apply(self, params, state, carry, *, train, seed):
        x = carry[0]
        if not train or self.rate <= 0.0:
            return carry, {}
        key = jax.random.fold_in(
            jax.random.PRNGKey(0), seed.astype(jnp.uint32) + jnp.uint32(self.salt))
        keep = 1.0 - self.rate
        mask = jax.random.bernoulli(key, keep, x.shape)
        return (jnp.where(mask, x / keep, 0.0),) + carry[1:], {}

    def out_shapes(self, shapes):
        return shapes


class ResStart(Op):
    """Push carry[0] as the residual skip value."""

    def __init__(self, name):
        self.name = name

    def apply(self, params, state, carry, *, train, seed):
        return (carry[0], carry[0]) + carry[1:], {}

    def out_shapes(self, shapes):
        return (shapes[0], shapes[0]) + shapes[1:]


class ResEnd(Op):
    """Pop the skip, apply the shortcut (identity or 1x1 projection+BN),
    and add. The activation after the add is a separate Act op."""

    def __init__(self, name, cin, cout, stride=1, momentum=0.9, eps=1e-5):
        self.name = name
        self.cin, self.cout, self.stride = cin, cout, stride
        self.project = (cin != cout) or (stride != 1)
        self.momentum, self.eps = momentum, eps

    def param_specs(self):
        if not self.project:
            return []
        return [(_p(self, "w"), (1, 1, self.cin, self.cout), "he", self.cin),
                (_p(self, "gamma"), (self.cout,), "ones", 0),
                (_p(self, "beta"), (self.cout,), "zeros", 0)]

    def state_specs(self):
        if not self.project:
            return []
        return [(_p(self, "mean"), (self.cout,), "zeros"),
                (_p(self, "var"), (self.cout,), "ones")]

    def apply(self, params, state, carry, *, train, seed):
        y, skip = carry[0], carry[1]
        updates = {}
        if self.project:
            s = conv2d(skip, params[_p(self, "w")], self.stride, "SAME")
            if train:
                axes = tuple(range(s.ndim - 1))
                mean, var = jnp.mean(s, axis=axes), jnp.var(s, axis=axes)
                m = self.momentum
                updates = {
                    _p(self, "mean"): m * state[_p(self, "mean")] + (1 - m) * mean,
                    _p(self, "var"): m * state[_p(self, "var")] + (1 - m) * var,
                }
            else:
                mean, var = state[_p(self, "mean")], state[_p(self, "var")]
            s = ((s - mean) * lax.rsqrt(var + self.eps)
                 * params[_p(self, "gamma")] + params[_p(self, "beta")])
        else:
            s = skip
        return (y + s,) + carry[2:], updates

    def out_shapes(self, shapes):
        return (shapes[0],) + shapes[2:]

    def flops_per_sample(self, shapes):
        n, h, w, c = shapes[0]
        f = h * w * c
        if self.project:
            f += 2 * h * w * self.cin * self.cout
        return f


# ---------------------------------------------------------------------------
# Layer: a paper-numbered group of ops
# ---------------------------------------------------------------------------

class Layer:
    """One paper-numbered layer: a pipeline register may follow it."""

    def __init__(self, name, ops):
        self.name = name
        self.ops = list(ops)

    def param_specs(self):
        return [s for op in self.ops for s in op.param_specs()]

    def state_specs(self):
        return [s for op in self.ops for s in op.state_specs()]

    def param_count(self):
        total = 0
        for nm, shape, _init, _fi in self.param_specs():
            n = 1
            for d in shape:
                n *= d
            total += n
        return total

    def apply(self, params, state, carry, *, train, seed):
        updates = {}
        for op in self.ops:
            carry, up = op.apply(params, state, carry, train=train, seed=seed)
            updates.update(up)
        return carry, updates

    def out_shapes(self, shapes):
        for op in self.ops:
            shapes = op.out_shapes(shapes)
        return shapes

    def flops_per_sample(self, shapes):
        total = 0
        for op in self.ops:
            total += op.flops_per_sample(shapes)
            shapes = op.out_shapes(shapes)
        return total


def init_value(shape, init, fan_in, rng):
    """Numpy initializer mirrored by the Rust side (model/init.rs)."""
    import numpy as np

    if init == "zeros":
        return np.zeros(shape, dtype=np.float32)
    if init == "ones":
        return np.ones(shape, dtype=np.float32)
    if init == "he":
        std = float(np.sqrt(2.0 / max(fan_in, 1)))
        return rng.normal(0.0, std, size=shape).astype(np.float32)
    if init == "glorot":
        fan_out = shape[-1]
        limit = float(np.sqrt(6.0 / (fan_in + fan_out)))
        return rng.uniform(-limit, limit, size=shape).astype(np.float32)
    raise ValueError(f"unknown init {init!r}")
